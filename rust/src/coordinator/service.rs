//! The long-running search job service behind `galen serve`.
//!
//! Speaks a line-oriented JSONL protocol over any `BufRead`/`Write` pair.
//! The protocol loop is transport-agnostic: the CLI wires stdin/stdout,
//! [`super::net`] wires TCP and Unix-socket connections over the same
//! shared job pool, and tests wire in-memory buffers — all three transports
//! produce byte-identical responses (the conformance suite asserts this).
//! Each request is one JSON object per line with an `op` field; each
//! response is one JSON object per line with `ok` plus the request's `id`
//! echoed back when present.  Operations:
//!
//! | op         | request fields                         | response                       |
//! |------------|----------------------------------------|--------------------------------|
//! | `hello`    | `protocol`, `require?`                 | `protocol`, `capabilities[]`   |
//! | `submit`   | `spec{agent, target, preset?, config?, variant?}` | `job`, `token`, `state` |
//! | `status`   | `job`, `token?`                        | `state`, `episode`, `episodes` |
//! | `events`   | `job`, `since?`, `token?`              | `events[]`, `next`             |
//! | `result`   | `job`, `wait?`, `token?`               | `state`, `outcome`, `policy`   |
//! | `cancel`   | `job`, `token?`                        | `state`                        |
//! | `forget`   | `job`, `token?`                        | `state` (events/outcome freed) |
//! | `list`     |                                        | `jobs[]`                       |
//! | `metrics`  |                                        | `metrics` (registry snapshot)  |
//! | `shutdown` |                                        | (serve loop exits)             |
//!
//! # Handshake, scoping and admission
//!
//! `hello` negotiates the protocol: the client sends the schema version it
//! speaks and optionally a `require` list of capabilities it depends on; a
//! mismatch is rejected with both versions echoed (`client_protocol` /
//! `server_protocol`) and the client may retry with a supported version.
//! Socket transports require a successful `hello` before any other op;
//! stdio keeps the handshake optional for backward compatibility with
//! pipeline scripts.
//!
//! Jobs are scoped to the connection that submitted them: `submit` returns
//! a capability `token`, and other connections can only observe or cancel
//! the job by presenting that token (`list` likewise shows only your own
//! and journal-restored jobs).  Tokens are deterministic per (seed, index)
//! — an access-scoping capability, not a cryptographic secret.
//!
//! Admission is bounded so overload degrades loudly instead of stalling:
//! when [`ServeOptions::max_queued_jobs`] is reached, `submit` answers a
//! structured `ok:false` carrying `retry_after_ms` (the connection cap in
//! [`super::net`] rejects the same way).  Request lines are capped at
//! [`MAX_REQUEST_LINE`] bytes; an oversized or non-UTF-8 line gets exactly
//! one `ok:false` and the connection keeps serving.
//!
//! Jobs multiplex over a fixed worker pool: each worker drives a
//! [`crate::search::SearchDriver`] episode by episode, streaming its
//! [`crate::search::SearchEvent`]s into the job's event log (what `events`
//! pages through) and honoring `cancel` at episode boundaries — the
//! granularity the driver state machine provides.  All workers share one
//! [`LatencyFactory`], so concurrent jobs reuse each other's latency-cache
//! entries exactly like parallel sweep workers do.
//!
//! # Failure model
//!
//! A worker is a fault boundary: each job runs under `catch_unwind`, so a
//! panic marks only its own job `failed` (with the panic message as the
//! error payload) while the service keeps accepting and completing other
//! jobs.  All service locks go through the poison-recovering
//! [`crate::util::sync`] helpers for the same reason.
//!
//! With a journal directory configured ([`ServeOptions::journal_dir`]),
//! every job transition is appended write-ahead to a durable JSONL journal
//! (see [`super::journal`]) and each job checkpoints its driver state every
//! [`ServeOptions::checkpoint_every`] episodes.  After a crash,
//! `galen serve --resume-jobs` replays the journal: terminal jobs are
//! restored as status records (like forgotten jobs — status and error
//! survive, events and outcomes do not), interrupted jobs are re-queued and
//! resume from their last checkpoint — or restart from episode 0 when no
//! usable checkpoint exists.  Both paths reproduce the uninterrupted run's
//! results bit for bit, because searches are deterministic functions of
//! their seed.  An unusable (truncated, corrupt, mismatched) checkpoint is
//! logged and discarded, never fatal.
//!
//! Accuracy is always the deterministic synthetic proxy
//! ([`crate::search::SimEvaluator`]): the PJRT evaluator is not
//! thread-safe, and stdout is the protocol channel.  Validate chosen
//! policies afterwards with `galen validate`.

use std::collections::VecDeque;
use std::fmt;
use std::io::{BufRead, Write};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::agent::mapper_for;
use crate::coordinator::journal::{replay_journal, ServeJournal, SERVE_JOURNAL_FILE};
use crate::coordinator::ExperimentRecord;
use crate::eval::SensitivityTable;
use crate::model::ModelIr;
use crate::obs;
use crate::search::{
    validate_checkpoint, LatencyFactory, SearchBuilder, SearchConfig, SearchDriver, SearchEvent,
    SearchOutcome, SimEvaluator,
};
use crate::testing::FaultPlan;
use crate::util::json::Json;
use crate::util::logging;
use crate::util::retry::Backoff;
use crate::util::sync;

// Registry handles for the service's process-wide series, resolved once
// per process.  Per-request verb histograms register through the map on
// each request instead — the protocol loop parses JSON and flushes a
// socket per line, so one cold map lookup is noise there, and verbs are a
// closed set so series cardinality stays bounded.
fn obs_queue_depth() -> &'static obs::Gauge {
    static G: OnceLock<obs::Gauge> = OnceLock::new();
    G.get_or_init(|| obs::Gauge::register("serve_queue_depth", &[]))
}

fn obs_active_jobs() -> &'static obs::Gauge {
    static G: OnceLock<obs::Gauge> = OnceLock::new();
    G.get_or_init(|| obs::Gauge::register("serve_active_jobs", &[]))
}

fn obs_jobs_completed() -> &'static obs::Counter {
    static C: OnceLock<obs::Counter> = OnceLock::new();
    C.get_or_init(|| obs::Counter::register("serve_jobs_completed_total", &[]))
}

fn obs_jobs_failed() -> &'static obs::Counter {
    static C: OnceLock<obs::Counter> = OnceLock::new();
    C.get_or_init(|| obs::Counter::register("serve_jobs_failed_total", &[]))
}

fn obs_jobs_resumed() -> &'static obs::Counter {
    static C: OnceLock<obs::Counter> = OnceLock::new();
    C.get_or_init(|| obs::Counter::register("serve_jobs_resumed_total", &[]))
}

fn obs_checkpoint_retries() -> &'static obs::Counter {
    static C: OnceLock<obs::Counter> = OnceLock::new();
    C.get_or_init(|| obs::Counter::register("serve_checkpoint_retries_total", &[]))
}

fn obs_connections_active() -> &'static obs::Gauge {
    static G: OnceLock<obs::Gauge> = OnceLock::new();
    G.get_or_init(|| obs::Gauge::register("serve_connections_active", &[]))
}

// Admission rejections by reason ("queue" here, "connections" in net.rs) —
// a closed label set, registered on the cold rejection path only.
pub(super) fn obs_admission_rejected(reason: &str) -> obs::Counter {
    obs::Counter::register("serve_admission_rejected_total", &[("reason", reason)])
}

/// Version of the JSONL protocol schema, negotiated by the `hello`
/// handshake (also echoed in `list` responses).  v2 added `hello`, job
/// tokens and bounded admission.
pub const SERVE_PROTOCOL_VERSION: usize = 2;

/// Upper bound on one request line, in bytes.  A line past the cap is
/// discarded up to its newline and answered with exactly one `ok:false` —
/// one hostile or broken client must not balloon service memory.
pub const MAX_REQUEST_LINE: usize = 256 * 1024;

/// Lifecycle state of one submitted job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobStatus {
    /// Waiting for a worker.
    Queued,
    /// A worker is driving its search.
    Running,
    /// Finished; the outcome is available.
    Done,
    /// The search errored; see the `error` field.
    Failed,
    /// Cancelled before completion.
    Cancelled,
}

impl JobStatus {
    /// Whether the job will never change state again.
    pub fn is_terminal(self) -> bool {
        matches!(self, JobStatus::Done | JobStatus::Failed | JobStatus::Cancelled)
    }
}

/// Stable lowercase label (protocol responses); honors format padding.
impl fmt::Display for JobStatus {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.pad(match self {
            Self::Queued => "queued",
            Self::Running => "running",
            Self::Done => "done",
            Self::Failed => "failed",
            Self::Cancelled => "cancelled",
        })
    }
}

/// Inverse of the [`fmt::Display`] labels (journal replay).
impl std::str::FromStr for JobStatus {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "queued" => Self::Queued,
            "running" => Self::Running,
            "done" => Self::Done,
            "failed" => Self::Failed,
            "cancelled" => Self::Cancelled,
            other => anyhow::bail!(
                "unknown job status '{other}' (queued|running|done|failed|cancelled)"
            ),
        })
    }
}

/// Knobs of one [`serve`] run.  The default runs on all cores, keeps
/// results in memory only, and journals nothing.
#[derive(Clone, Debug, Default)]
pub struct ServeOptions {
    /// Worker threads driving searches (0 = all cores).
    pub workers: usize,
    /// Where finished jobs' result records land (None = in-memory only).
    pub results_dir: Option<PathBuf>,
    /// Default search seed for submitted jobs (None keeps the presets'
    /// built-in seed); a spec's `config.seed` override always wins.
    pub base_seed: Option<u64>,
    /// Where the durable job journal and per-job checkpoints live (None =
    /// no durability: a crash loses in-flight jobs).
    pub journal_dir: Option<PathBuf>,
    /// Replay the journal on startup and re-queue interrupted jobs
    /// (requires `journal_dir`).
    pub resume_jobs: bool,
    /// Checkpoint each running job's driver every N episodes (0 = never;
    /// effective only with `journal_dir`).
    pub checkpoint_every: usize,
    /// Reject `submit` once this many jobs are waiting for a worker
    /// (0 = unbounded).  Rejections are structured `ok:false` responses
    /// carrying `retry_after_ms`, never a stalled protocol loop.
    pub max_queued_jobs: usize,
    /// The `retry_after_ms` hint sent with admission rejections
    /// (0 = the 500 ms default).
    pub retry_after_ms: u64,
    /// Armed fault injections (tests; the CLI wires `GALEN_FAULTS`).
    pub faults: FaultPlan,
    /// Package each completed job's outcome into a `.galen` artifact
    /// (`galen serve --package-dir`; built via `Session::packager`).
    /// Packaging failures are logged, never fail the job, and never alter
    /// protocol responses.
    pub packager: Option<super::Packager>,
}

/// Counters the serve loop reports when it exits.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeStats {
    /// Jobs accepted via `submit` this session.
    pub submitted: usize,
    /// Interrupted jobs re-queued from the journal by `--resume-jobs`.
    pub resumed: usize,
    /// Jobs that finished with an outcome.
    pub completed: usize,
    /// Jobs that errored.
    pub failed: usize,
    /// Jobs cancelled before completion.
    pub cancelled: usize,
}

/// How a job entered this serve session — determines what the exit stats
/// count (jobs already terminal in a replayed journal are bookkeeping, not
/// this session's work).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum JobOrigin {
    /// Accepted via `submit` this session.
    Submitted,
    /// Re-queued from the journal by `--resume-jobs`.
    Resumed,
    /// Replayed from the journal already terminal: a status record only.
    Restored,
}

/// Mutable job state behind the per-job mutex.
struct JobInner {
    status: JobStatus,
    episode: usize,
    cancel: bool,
    events: Vec<Json>,
    outcome: Option<SearchOutcome>,
    error: Option<String>,
    artifact: Option<PathBuf>,
}

/// One submitted job: identity + config outside the lock, state inside.
struct Job {
    id: String,
    cfg: SearchConfig,
    origin: JobOrigin,
    /// Connection that submitted it.  `None` for journal-replayed jobs —
    /// they pre-date every live connection, so any client may access them.
    owner: Option<u64>,
    /// Capability for cross-connection access: handed out in the submit
    /// response, required from every other connection.
    token: String,
    inner: Mutex<JobInner>,
    /// Signalled on every terminal transition (`result` with `wait` parks
    /// here).
    done: Condvar,
}

/// A job's capability token: a pure function of (service seed, job index),
/// so resumed sessions re-derive the same tokens their clients already
/// hold.  This is access *scoping* (which connection may touch which job),
/// not cryptography — serve listens on trusted interfaces.
fn job_token(seed: u64, index: usize) -> String {
    let mut h = crate::util::Fnv1a::seeded(seed ^ 0x9e37_79b9_7f4a_7c15);
    h.mix(0x6a6f_625f_746f_6b65); // "job_toke(n)"
    h.mix(index as u64);
    format!("{:016x}", h.finish())
}

/// Identity of one protocol connection.  The stdio transport is connection
/// 0 and skips the mandatory handshake (pipeline scripts pre-date `hello`);
/// socket connections get unique ids from the accept loop and must
/// handshake before any other op.
#[derive(Clone, Copy, Debug)]
pub(super) struct ConnCtx {
    /// Unique within one serve session; owner of the jobs it submits.
    pub(super) id: u64,
    /// Metric label: `stdio` | `tcp` | `unix` (closed set).
    pub(super) transport: &'static str,
    /// Whether ops before a successful `hello` are rejected.
    pub(super) require_hello: bool,
}

impl Job {
    fn terminal_transition(&self, f: impl FnOnce(&mut JobInner)) {
        let mut st = sync::lock(&self.inner);
        f(&mut st);
        drop(st);
        self.done.notify_all();
    }
}

/// Shared service state: the environment jobs run against plus the queue.
/// `pub(super)` so the socket front in [`super::net`] can run
/// [`protocol_loop`]s against it; fields stay private to this module.
pub(super) struct ServiceState<'a> {
    ir: &'a ModelIr,
    sens: &'a SensitivityTable,
    factory: &'a LatencyFactory,
    variant: String,
    results_dir: Option<PathBuf>,
    packager: Option<super::Packager>,
    base_seed: Option<u64>,
    journal: Option<Mutex<ServeJournal>>,
    checkpoint_dir: Option<PathBuf>,
    checkpoint_every: usize,
    max_queued: usize,
    retry_after_ms: u64,
    token_seed: u64,
    faults: FaultPlan,
    jobs: Mutex<Vec<Arc<Job>>>,
    queue: Mutex<VecDeque<usize>>,
    /// Signalled on submit and shutdown; idle workers park here instead of
    /// polling (a serve process is long-running — zero idle cost matters).
    queue_cv: Condvar,
    shutdown: AtomicBool,
}

impl ServiceState<'_> {
    fn checkpoint_path(&self, id: &str) -> Option<PathBuf> {
        self.checkpoint_dir.as_ref().map(|d| d.join(format!("{id}.json")))
    }

    /// Whether shutdown has been requested: fronts stop accepting, blocked
    /// reads give up their connections, workers drain the queue and exit.
    pub(super) fn draining(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// The backoff hint attached to admission rejections.
    pub(super) fn retry_hint_ms(&self) -> u64 {
        if self.retry_after_ms == 0 { 500 } else { self.retry_after_ms }
    }

    /// Flag the drain and wake parked workers.  The flag is published
    /// under the queue lock so a worker between its shutdown check and its
    /// wait cannot miss the wakeup.  Idempotent.
    pub(super) fn begin_drain(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let _queue = sync::lock(&self.queue);
        self.queue_cv.notify_all();
    }
}

/// Run the job service until `input` is exhausted (or a `shutdown` op),
/// then drain the queue and return the run's counters.
///
/// `ir`/`sens` describe the model every job searches; `factory` supplies
/// each job's latency provider with caches shared across workers;
/// `variant` names result records (`serve_<variant>_<job>.json`).
pub fn serve<R: BufRead, W: Write>(
    ir: &ModelIr,
    sens: &SensitivityTable,
    factory: &LatencyFactory,
    variant: &str,
    opts: &ServeOptions,
    input: R,
    output: &mut W,
) -> Result<ServeStats> {
    serve_with_front(ir, sens, factory, variant, opts, move |svc| {
        let conn = ConnCtx { id: 0, transport: "stdio", require_hello: false };
        protocol_loop(svc, &conn, input, output)
    })
}

/// The transport-generic service core: build the shared state, start the
/// worker pool, hand the state to `front` (a stdio protocol loop, or the
/// socket accept loop in [`super::net`]), then drain and tally.  When
/// `front` returns, shutdown is flagged (idempotent if the front already
/// did) so submitted work always drains before the stats are counted.
pub(super) fn serve_with_front<F>(
    ir: &ModelIr,
    sens: &SensitivityTable,
    factory: &LatencyFactory,
    variant: &str,
    opts: &ServeOptions,
    front: F,
) -> Result<ServeStats>
where
    F: FnOnce(&ServiceState<'_>) -> Result<()>,
{
    let workers = if opts.workers == 0 {
        crate::util::num_threads()
    } else {
        opts.workers
    };
    anyhow::ensure!(
        !opts.resume_jobs || opts.journal_dir.is_some(),
        "resuming jobs needs a journal: configure a results directory \
         (the journal lives alongside the result records)"
    );
    // tokens derive from the service seed so a resumed session re-derives
    // the tokens the previous session handed out
    let token_seed = opts.base_seed.unwrap_or(0x6761_6c65_6e);
    let mut initial_jobs: Vec<Arc<Job>> = Vec::new();
    let mut initial_queue: VecDeque<usize> = VecDeque::new();
    let mut journal = None;
    if let Some(dir) = &opts.journal_dir {
        if opts.resume_jobs {
            for (index, rj) in replay_journal(dir)?.into_iter().enumerate() {
                let terminal = rj.status.is_terminal();
                initial_jobs.push(Arc::new(Job {
                    id: rj.id,
                    cfg: rj.cfg,
                    origin: if terminal { JobOrigin::Restored } else { JobOrigin::Resumed },
                    owner: None,
                    token: job_token(token_seed, index),
                    inner: Mutex::new(JobInner {
                        status: if terminal { rj.status } else { JobStatus::Queued },
                        episode: 0,
                        cancel: false,
                        events: Vec::new(),
                        outcome: None,
                        error: rj.error,
                        artifact: None,
                    }),
                    done: Condvar::new(),
                }));
                if !terminal {
                    initial_queue.push_back(index);
                }
            }
        } else {
            refuse_or_clear_stale_journal(dir)?;
        }
        let mut j = ServeJournal::open_append(dir)?.with_faults(opts.faults.clone());
        for &index in &initial_queue {
            j.record_resumed(&initial_jobs[index].id)?;
        }
        journal = Some(Mutex::new(j));
    }
    if !initial_jobs.is_empty() {
        log::info!(
            "serve: journal replayed {} job(s), {} re-queued",
            initial_jobs.len(),
            initial_queue.len()
        );
    }
    if !initial_queue.is_empty() {
        obs_jobs_resumed().add(initial_queue.len() as u64);
    }
    obs_queue_depth().set(initial_queue.len() as f64);
    let svc = ServiceState {
        ir,
        sens,
        factory,
        variant: variant.to_string(),
        results_dir: opts.results_dir.clone(),
        packager: opts.packager.clone(),
        base_seed: opts.base_seed,
        journal,
        checkpoint_dir: opts.journal_dir.as_ref().map(|d| d.join("checkpoints")),
        checkpoint_every: opts.checkpoint_every,
        max_queued: opts.max_queued_jobs,
        retry_after_ms: opts.retry_after_ms,
        token_seed,
        faults: opts.faults.clone(),
        jobs: Mutex::new(initial_jobs),
        queue: Mutex::new(initial_queue),
        queue_cv: Condvar::new(),
        shutdown: AtomicBool::new(false),
    };
    log::info!("serve: {workers} workers, protocol v{SERVE_PROTOCOL_VERSION}");
    let protocol_result: Result<()> = std::thread::scope(|scope| {
        for w in 0..workers {
            let svc = &svc;
            scope.spawn(move || worker_loop(svc, w));
        }
        let r = front(&svc);
        // EOF (or front error): let the workers drain the queue and exit
        svc.begin_drain();
        r
    });
    protocol_result?;
    let mut stats = ServeStats::default();
    for job in sync::lock(&svc.jobs).iter() {
        match job.origin {
            // already terminal before this session: bookkeeping, not work
            JobOrigin::Restored => continue,
            JobOrigin::Resumed => stats.resumed += 1,
            JobOrigin::Submitted => stats.submitted += 1,
        }
        match sync::lock(&job.inner).status {
            JobStatus::Done => stats.completed += 1,
            JobStatus::Failed => stats.failed += 1,
            JobStatus::Cancelled => stats.cancelled += 1,
            // unreachable after the drain barrier, but don't miscount
            JobStatus::Queued | JobStatus::Running => {}
        }
    }
    log::info!(
        "serve: exit — {} submitted, {} resumed, {} done, {} failed, {} cancelled",
        stats.submitted,
        stats.resumed,
        stats.completed,
        stats.failed,
        stats.cancelled
    );
    Ok(stats)
}

/// A journal from a previous session, found while starting *without*
/// `--resume-jobs`: refuse if it records interrupted (recoverable) jobs —
/// never silently abandon work a client was promised — and otherwise clear
/// it so this session starts fresh.
fn refuse_or_clear_stale_journal(dir: &Path) -> Result<()> {
    let path = dir.join(SERVE_JOURNAL_FILE);
    if !path.exists() {
        return Ok(());
    }
    let replayed = replay_journal(dir)?;
    let interrupted: Vec<&str> = replayed
        .iter()
        .filter(|j| !j.status.is_terminal())
        .map(|j| j.id.as_str())
        .collect();
    anyhow::ensure!(
        interrupted.is_empty(),
        "serve journal {} records {} interrupted job(s) [{}] — restart with \
         --resume-jobs to recover them, or delete the journal to abandon them",
        path.display(),
        interrupted.len(),
        interrupted.join(", ")
    );
    // every journaled job finished: the previous session ended cleanly
    std::fs::remove_file(&path)
        .map_err(|e| anyhow::anyhow!("clearing completed serve journal {}: {e}", path.display()))?;
    let checkpoints = dir.join("checkpoints");
    if checkpoints.exists() {
        // stale checkpoints belong to the cleared journal's job ids
        let _ = std::fs::remove_dir_all(&checkpoints);
    }
    Ok(())
}

/// Append a status transition to the journal, if one is configured.  A
/// journal write failure degrades durability, not availability: it is
/// logged and the job proceeds.
fn journal_status(svc: &ServiceState<'_>, id: &str, status: JobStatus, error: Option<&str>) {
    if let Some(journal) = &svc.journal {
        if let Err(e) = sync::lock(journal).record_status(id, status, error) {
            log::warn!("serve: {id}: journal write failed ({e:#})");
        }
    }
}

/// What one [`LineReader::next_line`] call produced.
enum LineRead {
    /// One complete request line (without its newline).
    Line(Vec<u8>),
    /// A line past [`MAX_REQUEST_LINE`] was discarded; answer once.
    Oversized,
    /// Input exhausted.
    Eof,
    /// The service is draining; the connection gives up its read.
    Drained,
}

/// Incremental line framing over any [`BufRead`].  Unlike `read_line`, it
/// keeps a partial line across read timeouts — socket transports set one
/// so blocked connections notice shutdown, and clients legitimately split
/// writes mid-line (or dribble bytes, slow-loris style) — bounds line
/// length without buffering the excess, and serves a final unterminated
/// line at EOF.  Bytes are framed before UTF-8 conversion, so a multi-byte
/// character split across writes reassembles correctly.
struct LineReader {
    pending: Vec<u8>,
    /// Inside an over-long line: discard up to the next newline.
    overflowing: bool,
}

impl LineReader {
    fn new() -> Self {
        Self { pending: Vec::new(), overflowing: false }
    }

    fn next_line<R: BufRead>(
        &mut self,
        input: &mut R,
        draining: impl Fn() -> bool,
    ) -> std::io::Result<LineRead> {
        use std::io::ErrorKind;
        loop {
            let buf = match input.fill_buf() {
                Ok(buf) => buf,
                Err(e)
                    if matches!(
                        e.kind(),
                        ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted
                    ) =>
                {
                    if draining() {
                        return Ok(LineRead::Drained);
                    }
                    continue;
                }
                Err(e) => return Err(e),
            };
            if buf.is_empty() {
                // EOF: a final unterminated line is still a request (a
                // pipe script's last line often lacks its newline)
                if self.overflowing {
                    self.overflowing = false;
                    return Ok(LineRead::Oversized);
                }
                if self.pending.is_empty() {
                    return Ok(LineRead::Eof);
                }
                return Ok(LineRead::Line(std::mem::take(&mut self.pending)));
            }
            match buf.iter().position(|&b| b == b'\n') {
                Some(pos) => {
                    if self.overflowing {
                        input.consume(pos + 1);
                        self.overflowing = false;
                        return Ok(LineRead::Oversized);
                    }
                    self.pending.extend_from_slice(&buf[..pos]);
                    input.consume(pos + 1);
                    if self.pending.len() > MAX_REQUEST_LINE {
                        self.pending.clear();
                        return Ok(LineRead::Oversized);
                    }
                    return Ok(LineRead::Line(std::mem::take(&mut self.pending)));
                }
                None => {
                    let n = buf.len();
                    if !self.overflowing {
                        self.pending.extend_from_slice(buf);
                        if self.pending.len() > MAX_REQUEST_LINE {
                            self.pending.clear();
                            self.overflowing = true;
                        }
                    }
                    input.consume(n);
                }
            }
        }
    }
}

fn protocol_error(message: String) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::str(message)),
    ])
}

/// Read requests line by line, answer each with exactly one response line.
/// One loop serves every transport: stdio ([`serve`]), TCP and Unix
/// sockets ([`super::net`]) — responses are byte-identical across them.
pub(super) fn protocol_loop<R: BufRead, W: Write>(
    svc: &ServiceState<'_>,
    conn: &ConnCtx,
    input: R,
    output: &mut W,
) -> Result<()> {
    obs::Counter::register("serve_connections_total", &[("transport", conn.transport)]).inc();
    obs_connections_active().add(1.0);
    let result = protocol_loop_inner(svc, conn, input, output);
    obs_connections_active().add(-1.0);
    result
}

fn protocol_loop_inner<R: BufRead, W: Write>(
    svc: &ServiceState<'_>,
    conn: &ConnCtx,
    mut input: R,
    output: &mut W,
) -> Result<()> {
    // per-connection request counter, labelled by transport (closed set)
    let requests =
        obs::Counter::register("serve_requests_total", &[("transport", conn.transport)]);
    let mut reader = LineReader::new();
    let mut hello_done = false;
    loop {
        let bytes = match reader.next_line(&mut input, || svc.draining())? {
            LineRead::Eof | LineRead::Drained => break,
            LineRead::Oversized => {
                requests.inc();
                let r = protocol_error(format!(
                    "request line exceeds {MAX_REQUEST_LINE} bytes"
                ));
                writeln!(output, "{}", r.dump())?;
                output.flush()?;
                continue;
            }
            LineRead::Line(bytes) => bytes,
        };
        let Ok(line) = String::from_utf8(bytes) else {
            requests.inc();
            let r = protocol_error("request line is not valid utf-8".to_string());
            writeln!(output, "{}", r.dump())?;
            output.flush()?;
            continue;
        };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        requests.inc();
        let response = respond_to_line(svc, conn, &mut hello_done, line);
        writeln!(output, "{}", response.dump())?;
        output.flush()?;
        if svc.draining() {
            break;
        }
    }
    Ok(())
}

/// One request line to one response object: parse, handshake-gate,
/// dispatch, time, echo the id.
fn respond_to_line(
    svc: &ServiceState<'_>,
    conn: &ConnCtx,
    hello_done: &mut bool,
    line: &str,
) -> Json {
    let error_response = |e: anyhow::Error| protocol_error(format!("{e:#}"));
    // parse up front so even failing requests echo their correlation
    // id — pipelining clients must be able to match every response
    match Json::parse(line) {
        Err(e) => error_response(anyhow::anyhow!("bad request json: {e}")),
        Ok(req) => {
            // label by verb only for the closed op set — arbitrary
            // client strings must not mint unbounded metric series
            let verb = match req.get("op").and_then(Json::as_str) {
                Some(op) if SERVE_OPS.contains(&op) => op.to_string(),
                _ => "other".to_string(),
            };
            let _sp = obs::trace::span("serve_request").arg("verb", verb.clone());
            let t0 = Instant::now();
            let mut r = if verb == "hello" {
                match op_hello(svc, &req) {
                    Ok((r, accepted)) => {
                        *hello_done |= accepted;
                        r
                    }
                    Err(e) => error_response(e),
                }
            } else if conn.require_hello && !*hello_done {
                // a rejected or missing handshake gates everything else,
                // but the connection stays open: the client may retry
                // `hello` with a version this server speaks
                error_response(anyhow::anyhow!(
                    "handshake required: send {{\"op\":\"hello\",\"protocol\":{SERVE_PROTOCOL_VERSION}}} first"
                ))
            } else {
                match handle_request(svc, conn, &req) {
                    Ok(r) => r,
                    Err(e) => error_response(e),
                }
            };
            obs::Histogram::register(
                "serve_request_seconds",
                &[("verb", &verb)],
                &obs::latency_bounds(),
            )
            .observe_duration(t0.elapsed());
            if let (Json::Obj(m), Some(id)) = (&mut r, req.get("id")) {
                m.insert("id".to_string(), id.clone());
            }
            r
        }
    }
}

/// The closed set of protocol operations (also the valid per-verb metric
/// labels for `serve_request_seconds`, and the `hello` capability list).
const SERVE_OPS: &[&str] = &[
    "hello", "submit", "status", "events", "result", "cancel", "forget", "list", "metrics",
    "shutdown",
];

/// The `hello` handshake: the client states the protocol schema version it
/// speaks (and optionally capabilities it requires); a mismatch is
/// rejected with both versions echoed so the client can decide what to do.
/// Returns the response plus whether the handshake succeeded.
fn op_hello(svc: &ServiceState<'_>, req: &Json) -> Result<(Json, bool)> {
    const KEYS: &[&str] = &["op", "id", "protocol", "require"];
    let obj = req
        .as_obj()
        .ok_or_else(|| anyhow::anyhow!("hello request must be a JSON object"))?;
    for key in obj.keys() {
        anyhow::ensure!(
            KEYS.contains(&key.as_str()),
            "unknown hello key '{key}' (valid keys: {})",
            KEYS.join(", ")
        );
    }
    let client = req.req_usize("protocol")?;
    if client != SERVE_PROTOCOL_VERSION {
        return Ok((
            Json::obj(vec![
                ("ok", Json::Bool(false)),
                (
                    "error",
                    Json::str(format!(
                        "protocol version mismatch: client speaks v{client}, \
                         server speaks v{SERVE_PROTOCOL_VERSION}"
                    )),
                ),
                ("client_protocol", Json::num(client as f64)),
                ("server_protocol", Json::num(SERVE_PROTOCOL_VERSION as f64)),
            ]),
            false,
        ));
    }
    if let Some(required) = req.get("require") {
        let required = required.as_arr().ok_or_else(|| {
            anyhow::anyhow!("hello 'require' must be an array of capability strings")
        })?;
        let mut missing = Vec::new();
        for cap in required {
            let cap = cap.as_str().ok_or_else(|| {
                anyhow::anyhow!("hello 'require' must be an array of capability strings")
            })?;
            if !SERVE_OPS.contains(&cap) {
                missing.push(cap.to_string());
            }
        }
        if !missing.is_empty() {
            return Ok((
                Json::obj(vec![
                    ("ok", Json::Bool(false)),
                    (
                        "error",
                        Json::str(format!(
                            "unsupported capabilities: {}",
                            missing.join(", ")
                        )),
                    ),
                    ("capabilities", capabilities_json()),
                ]),
                false,
            ));
        }
    }
    Ok((
        Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("protocol", Json::num(SERVE_PROTOCOL_VERSION as f64)),
            ("capabilities", capabilities_json()),
            ("variant", Json::str(svc.variant.clone())),
        ]),
        true,
    ))
}

fn capabilities_json() -> Json {
    Json::Arr(SERVE_OPS.iter().map(|op| Json::str(*op)).collect())
}

fn handle_request(svc: &ServiceState<'_>, conn: &ConnCtx, req: &Json) -> Result<Json> {
    let op = req.req_str("op")?;
    match op {
        // "hello" never reaches here: the loop dispatches it pre-gate
        "submit" => op_submit(svc, conn, req),
        "status" => op_status(svc, conn, req),
        "events" => op_events(svc, conn, req),
        "result" => op_result(svc, conn, req),
        "cancel" => op_cancel(svc, conn, req),
        "forget" => op_forget(svc, conn, req),
        "list" => op_list(svc, conn),
        "metrics" => op_metrics(req),
        "shutdown" => {
            svc.shutdown.store(true, Ordering::SeqCst);
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("state", Json::str("shutdown")),
            ]))
        }
        other => anyhow::bail!(
            "unknown op '{other}' \
             (hello|submit|status|events|result|cancel|forget|list|metrics|shutdown)"
        ),
    }
}

/// Build a job's `SearchConfig` from a submit spec: required
/// `agent`/`target`, optional `preset` (fast|default|paper), a `config`
/// override object routed through `SearchConfig::apply_json` (unknown keys
/// rejected with the valid list), and an optional `variant` assertion —
/// a serve process hosts exactly one model, so a spec naming a different
/// variant is rejected up front instead of silently searching the wrong
/// model (clients submitting to a pool of serve processes pin their
/// intent this way).
fn config_from_spec(
    spec: &Json,
    base_seed: Option<u64>,
    served_variant: &str,
) -> Result<SearchConfig> {
    // same fail-loud contract as SearchConfig::apply_json: a typo like
    // "cofig" must not silently run the defaults
    const SPEC_KEYS: &[&str] = &["agent", "target", "preset", "config", "variant"];
    let obj = spec
        .as_obj()
        .ok_or_else(|| anyhow::anyhow!("submit 'spec' must be a JSON object"))?;
    for key in obj.keys() {
        anyhow::ensure!(
            SPEC_KEYS.contains(&key.as_str()),
            "unknown spec key '{key}' (valid keys: {})",
            SPEC_KEYS.join(", ")
        );
    }
    if let Some(v) = spec.get("variant") {
        let v = v
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("spec 'variant' must be a string"))?;
        anyhow::ensure!(
            v == served_variant,
            "spec wants variant '{v}' but this service searches '{served_variant}' \
             (start `galen serve --variant {v}` for that model)"
        );
    }
    let agent = spec.req_str("agent")?.parse()?;
    let target = spec.req_f64("target")?;
    let preset = match spec.get("preset") {
        None => "default",
        Some(p) => p
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("spec 'preset' must be a string"))?,
    };
    let mut cfg = match preset {
        "fast" => SearchConfig::fast(agent, target),
        "default" => SearchConfig::new(agent, target),
        "paper" => SearchConfig::paper(agent, target),
        other => anyhow::bail!("unknown preset '{other}' (fast|default|paper)"),
    };
    // progress flows through the event stream; episode logs would only
    // clutter stderr for every concurrent job
    cfg.log_every = 0;
    // the service's --seed is the default; an explicit config.seed wins
    if let Some(seed) = base_seed {
        cfg.seed = seed;
    }
    if let Some(overrides) = spec.get("config") {
        cfg.apply_json(overrides)?;
    }
    Ok(cfg)
}

fn op_submit(svc: &ServiceState<'_>, conn: &ConnCtx, req: &Json) -> Result<Json> {
    let cfg = config_from_spec(req.req("spec")?, svc.base_seed, &svc.variant)?;
    // Admission and enqueue are one critical section over BOTH maps.  The
    // drain check must be authoritative at enqueue time: with it outside
    // the lock, a submit racing a concurrent connection's `shutdown` could
    // journal-and-queue a job after the workers have already observed
    // (shutdown && queue empty) and exited — an accepted job nobody will
    // ever run, which the next session's journal replay would see as
    // interrupted work that never existed.  Lock order jobs -> queue is
    // deadlock-free: workers release the queue lock before touching jobs.
    let mut jobs = sync::lock(&svc.jobs);
    let mut queue = sync::lock(&svc.queue);
    anyhow::ensure!(!svc.draining(), "service is shutting down");
    if svc.max_queued > 0 && queue.len() >= svc.max_queued {
        obs_admission_rejected("queue").inc();
        return Ok(Json::obj(vec![
            ("ok", Json::Bool(false)),
            (
                "error",
                Json::str(format!(
                    "job queue is full ({} queued, max {}); retry later",
                    queue.len(),
                    svc.max_queued
                )),
            ),
            ("retry_after_ms", Json::num(svc.retry_hint_ms() as f64)),
        ]));
    }
    let index = jobs.len();
    let id = format!("job-{index}");
    // write-ahead, under the jobs lock: the journal's submission order is
    // the id order, and a job the journal cannot record is not accepted (a
    // failed append rolls the file back to its pre-append length, so the
    // unburned id is safely reused by the next submit)
    if let Some(journal) = &svc.journal {
        sync::lock(journal)
            .record_submitted(&id, &cfg)
            .map_err(|e| e.context("journaling submit (job not accepted)"))?;
    }
    let token = job_token(svc.token_seed, index);
    jobs.push(Arc::new(Job {
        id: id.clone(),
        cfg,
        origin: JobOrigin::Submitted,
        owner: Some(conn.id),
        token: token.clone(),
        inner: Mutex::new(JobInner {
            status: JobStatus::Queued,
            episode: 0,
            cancel: false,
            events: Vec::new(),
            outcome: None,
            error: None,
            artifact: None,
        }),
        done: Condvar::new(),
    }));
    drop(jobs);
    queue.push_back(index);
    obs_queue_depth().set(queue.len() as f64);
    svc.queue_cv.notify_one();
    drop(queue);
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("job", Json::str(id)),
        ("token", Json::str(token)),
        ("state", Json::str(JobStatus::Queued.to_string())),
    ]))
}

/// O(1) lookup: ids are `job-<index>` into the append-only jobs vec, so a
/// long-running service never pays a scan (under the global lock) per poll.
/// Enforces the scoping rule: a job is visible to the connection that
/// submitted it, to anyone presenting its `token`, and — for journal-
/// replayed jobs with no live owner — to everyone.
fn find_job(svc: &ServiceState<'_>, conn: &ConnCtx, req: &Json) -> Result<Arc<Job>> {
    let id = req.req_str("job")?;
    let index: Option<usize> = id.strip_prefix("job-").and_then(|n| n.parse().ok());
    let job = index
        .and_then(|i| sync::lock(&svc.jobs).get(i).cloned())
        .ok_or_else(|| anyhow::anyhow!("unknown job '{id}'"))?;
    let authorized = match job.owner {
        None => true,
        Some(owner) => {
            owner == conn.id
                || req.get("token").and_then(Json::as_str) == Some(job.token.as_str())
        }
    };
    anyhow::ensure!(
        authorized,
        "job '{id}' belongs to another connection (present its 'token' to access it)"
    );
    Ok(job)
}

fn op_status(svc: &ServiceState<'_>, conn: &ConnCtx, req: &Json) -> Result<Json> {
    let job = find_job(svc, conn, req)?;
    let st = sync::lock(&job.inner);
    let mut fields = vec![
        ("ok", Json::Bool(true)),
        ("job", Json::str(job.id.clone())),
        ("state", Json::str(st.status.to_string())),
        ("episode", Json::num(st.episode as f64)),
        ("episodes", Json::num(job.cfg.episodes as f64)),
    ];
    if let Some(e) = &st.error {
        fields.push(("error", Json::str(e.clone())));
    }
    Ok(Json::obj(fields))
}

fn op_events(svc: &ServiceState<'_>, conn: &ConnCtx, req: &Json) -> Result<Json> {
    let job = find_job(svc, conn, req)?;
    let since = req.get("since").and_then(Json::as_usize).unwrap_or(0);
    let st = sync::lock(&job.inner);
    let from = since.min(st.events.len());
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("job", Json::str(job.id.clone())),
        ("events", Json::Arr(st.events[from..].to_vec())),
        ("next", Json::num(st.events.len() as f64)),
    ]))
}

fn op_result(svc: &ServiceState<'_>, conn: &ConnCtx, req: &Json) -> Result<Json> {
    let job = find_job(svc, conn, req)?;
    let wait = req.get("wait").and_then(Json::as_bool).unwrap_or(false);
    let mut st = sync::lock(&job.inner);
    if wait {
        while !st.status.is_terminal() {
            st = sync::wait(&job.done, st);
        }
    }
    let mut fields = vec![
        ("ok", Json::Bool(true)),
        ("job", Json::str(job.id.clone())),
        ("state", Json::str(st.status.to_string())),
    ];
    if let Some(outcome) = &st.outcome {
        fields.push(("outcome", outcome.to_json()));
        fields.push(("policy", outcome.best_policy.to_json()));
    }
    if let Some(path) = &st.artifact {
        fields.push(("artifact", Json::str(path.display().to_string())));
    }
    if let Some(e) = &st.error {
        fields.push(("error", Json::str(e.clone())));
    }
    Ok(Json::obj(fields))
}

fn op_cancel(svc: &ServiceState<'_>, conn: &ConnCtx, req: &Json) -> Result<Json> {
    let job = find_job(svc, conn, req)?;
    let state = {
        let mut st = sync::lock(&job.inner);
        st.cancel = true;
        if st.status == JobStatus::Queued {
            // never reached a worker: terminal immediately
            st.status = JobStatus::Cancelled;
            job.done.notify_all();
        }
        st.status
    };
    if state == JobStatus::Cancelled {
        journal_status(svc, &job.id, JobStatus::Cancelled, None);
    }
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("job", Json::str(job.id.clone())),
        ("state", Json::str(state.to_string())),
    ]))
}

/// Release a terminal job's event log and outcome (the status line
/// survives).  A serve process is long-running and jobs are append-only,
/// so clients that fetched what they need bound the service's memory by
/// forgetting — without this every outcome and event stream would be
/// retained for the process lifetime.
fn op_forget(svc: &ServiceState<'_>, conn: &ConnCtx, req: &Json) -> Result<Json> {
    let job = find_job(svc, conn, req)?;
    let mut st = sync::lock(&job.inner);
    anyhow::ensure!(
        st.status.is_terminal(),
        "job '{}' is {} — only finished jobs can be forgotten",
        job.id,
        st.status
    );
    st.events = Vec::new();
    st.outcome = None;
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("job", Json::str(job.id.clone())),
        ("state", Json::str(st.status.to_string())),
    ]))
}

fn op_list(svc: &ServiceState<'_>, conn: &ConnCtx) -> Result<Json> {
    let jobs = sync::lock(&svc.jobs);
    let rows = jobs
        .iter()
        // the scoping rule, applied to enumeration: you see your own jobs
        // and ownerless journal-restored ones, never another client's
        .filter(|job| job.owner.is_none() || job.owner == Some(conn.id))
        .map(|job| {
            let st = sync::lock(&job.inner);
            Json::obj(vec![
                ("job", Json::str(job.id.clone())),
                ("agent", Json::str(job.cfg.agent.to_string())),
                ("target", Json::num(job.cfg.target)),
                ("state", Json::str(st.status.to_string())),
                ("episode", Json::num(st.episode as f64)),
                ("episodes", Json::num(job.cfg.episodes as f64)),
            ])
        })
        .collect();
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("protocol", Json::num(SERVE_PROTOCOL_VERSION as f64)),
        ("jobs", Json::Arr(rows)),
    ]))
}

/// The live registry snapshot (`op: "metrics"`): everything the process
/// has recorded — this service's request/queue/job series, the drivers'
/// search series, the latency backends' cache and measurement series.
/// Strict like every other op: only `op` and `id` are valid keys, so a
/// typoed filter field fails loudly instead of silently returning the
/// whole snapshot.
fn op_metrics(req: &Json) -> Result<Json> {
    const KEYS: &[&str] = &["op", "id"];
    let obj = req
        .as_obj()
        .ok_or_else(|| anyhow::anyhow!("metrics request must be a JSON object"))?;
    for key in obj.keys() {
        anyhow::ensure!(
            KEYS.contains(&key.as_str()),
            "unknown metrics key '{key}' (valid keys: {})",
            KEYS.join(", ")
        );
    }
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("metrics", obs::MetricsSnapshot::capture().to_json()),
    ]))
}

/// Pull jobs off the queue until shutdown is flagged *and* the queue is
/// empty — submitted work always drains, even when the client hangs up
/// right after submitting.  Idle workers park on the queue condvar (no
/// polling); submit and shutdown wake them.  Every log line from this
/// thread carries the worker's id (`w<n>`, or `w<n>/<job>` while driving
/// a job) via the thread-local logging context.
fn worker_loop(svc: &ServiceState<'_>, worker: usize) {
    let _ctx = logging::push_context(format!("w{worker}"));
    let mut queue = sync::lock(&svc.queue);
    loop {
        if let Some(index) = queue.pop_front() {
            obs_queue_depth().set(queue.len() as f64);
            drop(queue);
            // the jobs lock is taken only after the queue guard is gone:
            // op_submit holds jobs -> queue, so holding queue -> jobs here
            // would be an ABBA deadlock
            let job = sync::lock(&svc.jobs)[index].clone();
            let _job_ctx = logging::push_context(format!("w{worker}/{}", job.id));
            run_job(svc, &job);
            drop(_job_ctx);
            queue = sync::lock(&svc.queue);
            continue;
        }
        if svc.shutdown.load(Ordering::SeqCst) {
            return;
        }
        queue = sync::wait(&svc.queue_cv, queue);
    }
}

/// The panic payload's message, for the failed job's error field.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    payload
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic payload".to_string())
}

/// Drive one job start to finish on this worker thread.  The job is a
/// fault boundary: a panic anywhere in the search marks this job `failed`
/// and the worker moves on to the next one.
fn run_job(svc: &ServiceState<'_>, job: &Arc<Job>) {
    {
        let mut st = sync::lock(&job.inner);
        if st.status.is_terminal() {
            return; // cancelled while queued (op_cancel journaled it)
        }
        if st.cancel {
            st.status = JobStatus::Cancelled;
            drop(st);
            journal_status(svc, &job.id, JobStatus::Cancelled, None);
            job.done.notify_all();
            return;
        }
        st.status = JobStatus::Running;
    }
    journal_status(svc, &job.id, JobStatus::Running, None);
    obs_active_jobs().add(1.0);
    let _sp = obs::trace::span("serve_job")
        .arg("job", job.id.clone())
        .arg("agent", job.cfg.agent.to_string());
    log::info!("serve: {} started ({} c={})", job.id, job.cfg.agent, job.cfg.target);
    let result = match catch_unwind(AssertUnwindSafe(|| drive_job(svc, job))) {
        Ok(r) => r,
        Err(payload) => Err(anyhow::anyhow!(
            "worker panicked: {}",
            panic_message(&*payload)
        )),
    };
    match result {
        Ok(Some((outcome, artifact))) => {
            journal_status(svc, &job.id, JobStatus::Done, None);
            obs_jobs_completed().inc();
            job.terminal_transition(|st| {
                st.outcome = Some(outcome);
                st.artifact = artifact;
                st.status = JobStatus::Done;
            });
        }
        Ok(None) => {
            journal_status(svc, &job.id, JobStatus::Cancelled, None);
            job.terminal_transition(|st| st.status = JobStatus::Cancelled);
        }
        Err(e) => {
            let msg = format!("{e:#}");
            log::warn!("serve: {} failed: {msg}", job.id);
            journal_status(svc, &job.id, JobStatus::Failed, Some(&msg));
            obs_jobs_failed().inc();
            job.terminal_transition(|st| {
                st.error = Some(msg);
                st.status = JobStatus::Failed;
            });
        }
    }
    obs_active_jobs().add(-1.0);
}

/// Load a resumed job's checkpoint leniently: any problem — missing file,
/// unreadable, garbage JSON, schema/config mismatch — is logged and the
/// job restarts from episode 0 (determinism makes both paths reproduce the
/// same result; a bad checkpoint must never strand a recoverable job).
fn load_checkpoint(svc: &ServiceState<'_>, job: &Job, path: &Path) -> Option<Json> {
    // reap temps a crashed process left between create and rename
    crate::util::json::cleanup_stale_temps(path);
    if !path.exists() {
        log::info!(
            "serve: {}: no checkpoint at {}; restarting from episode 0",
            job.id,
            path.display()
        );
        return None;
    }
    let attempt = (|| -> Result<Json> {
        let mut text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        svc.faults.corrupt("checkpoint-read", &mut text)?;
        let doc = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        validate_checkpoint(&doc, &job.cfg)?;
        Ok(doc)
    })();
    match attempt {
        Ok(doc) => Some(doc),
        Err(e) => {
            log::warn!(
                "serve: {}: unusable checkpoint ({e:#}); restarting from episode 0",
                job.id
            );
            None
        }
    }
}

/// Write an episode-aligned checkpoint if one is due, retrying transient
/// write failures with deterministic backoff.  A checkpoint that still
/// fails is logged and skipped: it degrades crash recovery (resume falls
/// back to an older checkpoint or episode 0), never the job itself.
fn maybe_checkpoint(svc: &ServiceState<'_>, job: &Job, driver: &SearchDriver<'_>) {
    let Some(path) = svc.checkpoint_path(&job.id) else {
        return;
    };
    if svc.checkpoint_every == 0 || driver.episode() % svc.checkpoint_every != 0 {
        return;
    }
    let doc = match driver.save_checkpoint() {
        Ok(doc) => doc,
        Err(e) => {
            log::warn!("serve: {}: checkpoint build failed ({e:#})", job.id);
            return;
        }
    };
    let backoff = Backoff::new(
        3,
        Duration::from_millis(10),
        Duration::from_millis(200),
        job.cfg.seed,
    );
    let written = backoff.run(|attempt| {
        if attempt > 0 {
            obs_checkpoint_retries().inc();
        }
        svc.faults.trip("checkpoint-write")?;
        doc.write_file_atomic(&path)
    });
    if let Err(e) = written {
        log::warn!(
            "serve: {}: checkpoint write to {} failed ({e:#}); continuing without",
            job.id,
            path.display()
        );
    }
}

/// The worker-side search: a driver run episode by episode, events teed
/// into the job log, cancellation honored between episodes, driver state
/// checkpointed at the configured cadence.  Returns `Ok(None)` when
/// cancelled.
fn drive_job(
    svc: &ServiceState<'_>,
    job: &Arc<Job>,
) -> Result<Option<(SearchOutcome, Option<PathBuf>)>> {
    let evaluator = SimEvaluator::new(svc.ir);
    // same per-search seed split as Session::search / sweep workers
    let mut provider = svc.factory.provider(job.cfg.seed ^ 0x5117, svc.ir)?;
    let mapper = mapper_for(job.cfg.agent);
    let resume_doc = match svc.checkpoint_path(&job.id) {
        Some(path) if job.origin == JobOrigin::Resumed => load_checkpoint(svc, job, &path),
        _ => None,
    };
    let mut driver = match &resume_doc {
        Some(doc) => SearchDriver::resume_from(
            doc,
            svc.ir,
            svc.sens,
            &evaluator,
            provider.as_mut(),
            mapper.as_ref(),
        )?,
        None => SearchBuilder::from_config(job.cfg.clone()).build(
            svc.ir,
            svc.sens,
            &evaluator,
            provider.as_mut(),
            mapper.as_ref(),
        )?,
    };
    if driver.episode() > 0 {
        log::info!(
            "serve: {} resumed from checkpoint at episode {}/{}",
            job.id,
            driver.episode(),
            job.cfg.episodes
        );
        sync::lock(&job.inner).episode = driver.episode();
    }
    let sink = job.clone();
    driver.add_observer(move |event: &SearchEvent| {
        let mut st = sync::lock(&sink.inner);
        if let SearchEvent::EpisodeFinished(s) = event {
            st.episode = s.episode + 1;
        }
        st.events.push(event.to_json());
    });
    let mut cancelled_at = None;
    loop {
        // completion wins over a cancel landing during the final episode:
        // "cancel at the next episode boundary" has no boundary left, and
        // the event stream has already announced `finished`
        if driver.is_done() {
            break;
        }
        if sync::lock(&job.inner).cancel {
            cancelled_at = Some(driver.episode());
            break;
        }
        if driver.run_episode()?.is_none() {
            break;
        }
        // fault site "episode": the worst-case crash window — the episode
        // finished but its checkpoint has not been persisted yet
        svc.faults.trip("episode")?;
        maybe_checkpoint(svc, job, &driver);
    }
    let outcome = if cancelled_at.is_none() {
        Some(driver.outcome()?)
    } else {
        None
    };
    drop(driver);
    // persist even on the cancel path: measured/hybrid backends already
    // paid for their kernel measurements, the next job should reuse them.
    // A cache persist failure costs future cache hits, not this job's
    // already-computed outcome.
    if let Err(e) = provider.persist() {
        log::warn!("serve: {}: latency cache persist failed ({e:#})", job.id);
    }
    let Some(outcome) = outcome else {
        log::info!(
            "serve: {} cancelled at episode {}",
            job.id,
            cancelled_at.unwrap_or(0)
        );
        return Ok(None);
    };
    let artifact = match &svc.results_dir {
        None => None,
        Some(dir) => {
            let record = ExperimentRecord {
                name: format!("serve_{}_{}", svc.variant, job.id),
                config: job.cfg.clone(),
                outcome: outcome.clone(),
            };
            Some(record.save(svc.ir, dir)?)
        }
    };
    if let Some(packager) = &svc.packager {
        // packaging is a best-effort extra deliverable: a failure (e.g. an
        // unwritable package dir) must not fail a job whose search succeeded
        match packager.package(&outcome) {
            Ok(path) => log::info!("serve: {} packaged -> {}", job.id, path.display()),
            Err(e) => log::warn!("serve: {} packaging failed: {e:#}", job.id),
        }
    }
    log::info!(
        "serve: {} done (best reward {:+.4}, rel.lat {:.1}%)",
        job.id,
        outcome.best.reward,
        outcome.relative_latency() * 100.0
    );
    Ok(Some((outcome, artifact)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::VecDeque as Deque;
    use std::io::{self, Read};

    /// A scripted `BufRead`: yields chunks (or errors) one `fill_buf` at a
    /// time, the way a socket delivers split writes and read timeouts.
    struct Feed {
        chunks: Deque<io::Result<Vec<u8>>>,
        cur: Vec<u8>,
        pos: usize,
    }

    impl Feed {
        fn new(chunks: Vec<io::Result<Vec<u8>>>) -> Self {
            Self { chunks: chunks.into_iter().collect(), cur: Vec::new(), pos: 0 }
        }
    }

    impl Read for Feed {
        fn read(&mut self, out: &mut [u8]) -> io::Result<usize> {
            let avail = self.fill_buf()?;
            let n = avail.len().min(out.len());
            out[..n].copy_from_slice(&avail[..n]);
            self.consume(n);
            Ok(n)
        }
    }

    impl BufRead for Feed {
        fn fill_buf(&mut self) -> io::Result<&[u8]> {
            if self.pos >= self.cur.len() {
                match self.chunks.pop_front() {
                    None => {
                        self.cur.clear();
                        self.pos = 0;
                    }
                    Some(Ok(chunk)) => {
                        self.cur = chunk;
                        self.pos = 0;
                    }
                    Some(Err(e)) => return Err(e),
                }
            }
            Ok(&self.cur[self.pos..])
        }

        fn consume(&mut self, n: usize) {
            self.pos += n;
        }
    }

    fn timeout() -> io::Error {
        io::Error::new(io::ErrorKind::WouldBlock, "read timed out")
    }

    fn line(reader: &mut LineReader, feed: &mut Feed) -> String {
        match reader.next_line(feed, || false).unwrap() {
            LineRead::Line(bytes) => String::from_utf8(bytes).unwrap(),
            other => panic!("expected a line, got {}", kind(&other)),
        }
    }

    fn kind(r: &LineRead) -> &'static str {
        match r {
            LineRead::Line(_) => "line",
            LineRead::Oversized => "oversized",
            LineRead::Eof => "eof",
            LineRead::Drained => "drained",
        }
    }

    #[test]
    fn split_writes_and_timeouts_reassemble_one_line() {
        // a request split across 3 writes with timeouts in between, and a
        // multi-byte UTF-8 character ("é" = 0xC3 0xA9) split mid-character
        let mut feed = Feed::new(vec![
            Ok(b"{\"op\":\"li".to_vec()),
            Err(timeout()),
            Ok(vec![0xC3]),
            Err(timeout()),
            Ok(vec![0xA9]),
            Ok(b"st\"}\n".to_vec()),
        ]);
        let mut reader = LineReader::new();
        assert_eq!(line(&mut reader, &mut feed), "{\"op\":\"li\u{e9}st\"}");
        assert!(matches!(reader.next_line(&mut feed, || false).unwrap(), LineRead::Eof));
    }

    #[test]
    fn timeout_while_draining_gives_up_but_keeps_nothing_half_read() {
        let mut feed = Feed::new(vec![Ok(b"{\"op\"".to_vec()), Err(timeout())]);
        let mut reader = LineReader::new();
        assert!(matches!(
            reader.next_line(&mut feed, || true).unwrap(),
            LineRead::Drained
        ));
    }

    #[test]
    fn final_unterminated_line_is_served_at_eof() {
        let mut feed = Feed::new(vec![Ok(b"a\nb".to_vec())]);
        let mut reader = LineReader::new();
        assert_eq!(line(&mut reader, &mut feed), "a");
        assert_eq!(line(&mut reader, &mut feed), "b");
        assert!(matches!(reader.next_line(&mut feed, || false).unwrap(), LineRead::Eof));
    }

    #[test]
    fn oversized_line_is_discarded_without_buffering_and_stream_recovers() {
        let mut huge = vec![b'x'; MAX_REQUEST_LINE + 10];
        huge.push(b'\n');
        huge.extend_from_slice(b"ok\n");
        let mut feed = Feed::new(vec![Ok(huge)]);
        let mut reader = LineReader::new();
        assert!(matches!(
            reader.next_line(&mut feed, || false).unwrap(),
            LineRead::Oversized
        ));
        assert!(reader.pending.capacity() <= 2 * MAX_REQUEST_LINE, "excess was buffered");
        assert_eq!(line(&mut reader, &mut feed), "ok");
    }

    #[test]
    fn oversized_line_cut_by_eof_still_reports_once() {
        let mut feed = Feed::new(vec![Ok(vec![b'x'; MAX_REQUEST_LINE + 1])]);
        let mut reader = LineReader::new();
        assert!(matches!(
            reader.next_line(&mut feed, || false).unwrap(),
            LineRead::Oversized
        ));
        assert!(matches!(reader.next_line(&mut feed, || false).unwrap(), LineRead::Eof));
    }

    #[test]
    fn job_tokens_are_deterministic_and_distinct() {
        assert_eq!(job_token(7, 0), job_token(7, 0), "resume must re-derive tokens");
        assert_ne!(job_token(7, 0), job_token(7, 1));
        assert_ne!(job_token(7, 0), job_token(8, 0));
        assert_eq!(job_token(7, 3).len(), 16, "16 hex chars");
    }
}
