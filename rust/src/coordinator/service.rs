//! The long-running search job service behind `galen serve`.
//!
//! Speaks a line-oriented JSONL protocol over any `BufRead`/`Write` pair
//! (the CLI wires stdin/stdout; tests wire in-memory buffers).  Each
//! request is one JSON object per line with an `op` field; each response is
//! one JSON object per line with `ok` plus the request's `id` echoed back
//! when present.  Operations:
//!
//! | op         | request fields                         | response                       |
//! |------------|----------------------------------------|--------------------------------|
//! | `submit`   | `spec{agent, target, preset?, config?, variant?}` | `job`, `state`      |
//! | `status`   | `job`                                  | `state`, `episode`, `episodes` |
//! | `events`   | `job`, `since?`                        | `events[]`, `next`             |
//! | `result`   | `job`, `wait?`                         | `state`, `outcome`, `policy`   |
//! | `cancel`   | `job`                                  | `state`                        |
//! | `forget`   | `job`                                  | `state` (events/outcome freed) |
//! | `list`     |                                        | `jobs[]`                       |
//! | `shutdown` |                                        | (serve loop exits)             |
//!
//! Jobs multiplex over a fixed worker pool: each worker drives a
//! [`crate::search::SearchDriver`] episode by episode, streaming its
//! [`crate::search::SearchEvent`]s into the job's event log (what `events`
//! pages through) and honoring `cancel` at episode boundaries — the
//! granularity the driver state machine provides.  All workers share one
//! [`LatencyFactory`], so concurrent jobs reuse each other's latency-cache
//! entries exactly like parallel sweep workers do.
//!
//! Accuracy is always the deterministic synthetic proxy
//! ([`crate::search::SimEvaluator`]): the PJRT evaluator is not
//! thread-safe, and stdout is the protocol channel.  Validate chosen
//! policies afterwards with `galen validate`.

use std::collections::VecDeque;
use std::fmt;
use std::io::{BufRead, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::Result;

use crate::agent::mapper_for;
use crate::coordinator::ExperimentRecord;
use crate::eval::SensitivityTable;
use crate::model::ModelIr;
use crate::search::{
    LatencyFactory, SearchBuilder, SearchConfig, SearchEvent, SearchOutcome, SimEvaluator,
};
use crate::util::json::Json;

/// Version of the JSONL protocol (the `hello`-less handshake: clients can
/// check it via `list` responses).
pub const SERVE_PROTOCOL_VERSION: usize = 1;

/// Lifecycle state of one submitted job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobStatus {
    /// Waiting for a worker.
    Queued,
    /// A worker is driving its search.
    Running,
    /// Finished; the outcome is available.
    Done,
    /// The search errored; see the `error` field.
    Failed,
    /// Cancelled before completion.
    Cancelled,
}

impl JobStatus {
    /// Whether the job will never change state again.
    pub fn is_terminal(self) -> bool {
        matches!(self, JobStatus::Done | JobStatus::Failed | JobStatus::Cancelled)
    }
}

/// Stable lowercase label (protocol responses); honors format padding.
impl fmt::Display for JobStatus {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.pad(match self {
            Self::Queued => "queued",
            Self::Running => "running",
            Self::Done => "done",
            Self::Failed => "failed",
            Self::Cancelled => "cancelled",
        })
    }
}

/// Knobs of one [`serve`] run.  The default runs on all cores and keeps
/// results in memory only.
#[derive(Clone, Debug, Default)]
pub struct ServeOptions {
    /// Worker threads driving searches (0 = all cores).
    pub workers: usize,
    /// Where finished jobs' result records land (None = in-memory only).
    pub results_dir: Option<PathBuf>,
    /// Default search seed for submitted jobs (None keeps the presets'
    /// built-in seed); a spec's `config.seed` override always wins.
    pub base_seed: Option<u64>,
}

/// Counters the serve loop reports when it exits.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeStats {
    /// Jobs accepted via `submit`.
    pub submitted: usize,
    /// Jobs that finished with an outcome.
    pub completed: usize,
    /// Jobs that errored.
    pub failed: usize,
    /// Jobs cancelled before completion.
    pub cancelled: usize,
}

/// Mutable job state behind the per-job mutex.
struct JobInner {
    status: JobStatus,
    episode: usize,
    cancel: bool,
    events: Vec<Json>,
    outcome: Option<SearchOutcome>,
    error: Option<String>,
    artifact: Option<PathBuf>,
}

/// One submitted job: identity + config outside the lock, state inside.
struct Job {
    id: String,
    cfg: SearchConfig,
    inner: Mutex<JobInner>,
    /// Signalled on every terminal transition (`result` with `wait` parks
    /// here).
    done: Condvar,
}

impl Job {
    fn terminal_transition(&self, f: impl FnOnce(&mut JobInner)) {
        let mut st = self.inner.lock().unwrap();
        f(&mut st);
        drop(st);
        self.done.notify_all();
    }
}

/// Shared service state: the environment jobs run against plus the queue.
struct ServiceState<'a> {
    ir: &'a ModelIr,
    sens: &'a SensitivityTable,
    factory: &'a LatencyFactory,
    variant: String,
    results_dir: Option<PathBuf>,
    base_seed: Option<u64>,
    jobs: Mutex<Vec<Arc<Job>>>,
    queue: Mutex<VecDeque<usize>>,
    /// Signalled on submit and shutdown; idle workers park here instead of
    /// polling (a serve process is long-running — zero idle cost matters).
    queue_cv: Condvar,
    shutdown: AtomicBool,
}

/// Run the job service until `input` is exhausted (or a `shutdown` op),
/// then drain the queue and return the run's counters.
///
/// `ir`/`sens` describe the model every job searches; `factory` supplies
/// each job's latency provider with caches shared across workers;
/// `variant` names result records (`serve_<variant>_<job>.json`).
pub fn serve<R: BufRead, W: Write>(
    ir: &ModelIr,
    sens: &SensitivityTable,
    factory: &LatencyFactory,
    variant: &str,
    opts: &ServeOptions,
    input: R,
    output: &mut W,
) -> Result<ServeStats> {
    let workers = if opts.workers == 0 {
        crate::util::num_threads()
    } else {
        opts.workers
    };
    let svc = ServiceState {
        ir,
        sens,
        factory,
        variant: variant.to_string(),
        results_dir: opts.results_dir.clone(),
        base_seed: opts.base_seed,
        jobs: Mutex::new(Vec::new()),
        queue: Mutex::new(VecDeque::new()),
        queue_cv: Condvar::new(),
        shutdown: AtomicBool::new(false),
    };
    log::info!("serve: {workers} workers, protocol v{SERVE_PROTOCOL_VERSION}");
    let protocol_result: Result<()> = std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| worker_loop(&svc));
        }
        let r = protocol_loop(&svc, input, output);
        // EOF (or error): let the workers drain the queue and exit.  The
        // flag is published under the queue lock so a worker between its
        // shutdown check and its wait cannot miss the wakeup.
        svc.shutdown.store(true, Ordering::SeqCst);
        let _queue = svc.queue.lock().unwrap();
        svc.queue_cv.notify_all();
        drop(_queue);
        r
    });
    protocol_result?;
    let mut stats = ServeStats::default();
    for job in svc.jobs.lock().unwrap().iter() {
        stats.submitted += 1;
        match job.inner.lock().unwrap().status {
            JobStatus::Done => stats.completed += 1,
            JobStatus::Failed => stats.failed += 1,
            JobStatus::Cancelled => stats.cancelled += 1,
            // unreachable after the drain barrier, but don't miscount
            JobStatus::Queued | JobStatus::Running => {}
        }
    }
    log::info!(
        "serve: exit — {} submitted, {} done, {} failed, {} cancelled",
        stats.submitted,
        stats.completed,
        stats.failed,
        stats.cancelled
    );
    Ok(stats)
}

/// Read requests line by line, answer each with exactly one response line.
fn protocol_loop<R: BufRead, W: Write>(
    svc: &ServiceState<'_>,
    input: R,
    output: &mut W,
) -> Result<()> {
    for line in input.lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let error_response = |e: anyhow::Error| {
            Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::str(format!("{e:#}"))),
            ])
        };
        // parse up front so even failing requests echo their correlation
        // id — pipelining clients must be able to match every response
        let response = match Json::parse(line) {
            Err(e) => error_response(anyhow::anyhow!("bad request json: {e}")),
            Ok(req) => {
                let mut r = match handle_request(svc, &req) {
                    Ok(j) => j,
                    Err(e) => error_response(e),
                };
                if let (Json::Obj(m), Some(id)) = (&mut r, req.get("id")) {
                    m.insert("id".to_string(), id.clone());
                }
                r
            }
        };
        writeln!(output, "{}", response.dump())?;
        output.flush()?;
        if svc.shutdown.load(Ordering::SeqCst) {
            break;
        }
    }
    Ok(())
}

fn handle_request(svc: &ServiceState<'_>, req: &Json) -> Result<Json> {
    let op = req.req_str("op")?;
    match op {
        "submit" => op_submit(svc, req),
        "status" => op_status(svc, req),
        "events" => op_events(svc, req),
        "result" => op_result(svc, req),
        "cancel" => op_cancel(svc, req),
        "forget" => op_forget(svc, req),
        "list" => op_list(svc),
        "shutdown" => {
            svc.shutdown.store(true, Ordering::SeqCst);
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("state", Json::str("shutdown")),
            ]))
        }
        other => anyhow::bail!(
            "unknown op '{other}' (submit|status|events|result|cancel|forget|list|shutdown)"
        ),
    }
}

/// Build a job's `SearchConfig` from a submit spec: required
/// `agent`/`target`, optional `preset` (fast|default|paper), a `config`
/// override object routed through `SearchConfig::apply_json` (unknown keys
/// rejected with the valid list), and an optional `variant` assertion —
/// a serve process hosts exactly one model, so a spec naming a different
/// variant is rejected up front instead of silently searching the wrong
/// model (clients submitting to a pool of serve processes pin their
/// intent this way).
fn config_from_spec(
    spec: &Json,
    base_seed: Option<u64>,
    served_variant: &str,
) -> Result<SearchConfig> {
    // same fail-loud contract as SearchConfig::apply_json: a typo like
    // "cofig" must not silently run the defaults
    const SPEC_KEYS: &[&str] = &["agent", "target", "preset", "config", "variant"];
    let obj = spec
        .as_obj()
        .ok_or_else(|| anyhow::anyhow!("submit 'spec' must be a JSON object"))?;
    for key in obj.keys() {
        anyhow::ensure!(
            SPEC_KEYS.contains(&key.as_str()),
            "unknown spec key '{key}' (valid keys: {})",
            SPEC_KEYS.join(", ")
        );
    }
    if let Some(v) = spec.get("variant") {
        let v = v
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("spec 'variant' must be a string"))?;
        anyhow::ensure!(
            v == served_variant,
            "spec wants variant '{v}' but this service searches '{served_variant}' \
             (start `galen serve --variant {v}` for that model)"
        );
    }
    let agent = spec.req_str("agent")?.parse()?;
    let target = spec.req_f64("target")?;
    let preset = match spec.get("preset") {
        None => "default",
        Some(p) => p
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("spec 'preset' must be a string"))?,
    };
    let mut cfg = match preset {
        "fast" => SearchConfig::fast(agent, target),
        "default" => SearchConfig::new(agent, target),
        "paper" => SearchConfig::paper(agent, target),
        other => anyhow::bail!("unknown preset '{other}' (fast|default|paper)"),
    };
    // progress flows through the event stream; episode logs would only
    // clutter stderr for every concurrent job
    cfg.log_every = 0;
    // the service's --seed is the default; an explicit config.seed wins
    if let Some(seed) = base_seed {
        cfg.seed = seed;
    }
    if let Some(overrides) = spec.get("config") {
        cfg.apply_json(overrides)?;
    }
    Ok(cfg)
}

fn op_submit(svc: &ServiceState<'_>, req: &Json) -> Result<Json> {
    anyhow::ensure!(
        !svc.shutdown.load(Ordering::SeqCst),
        "service is shutting down"
    );
    let cfg = config_from_spec(req.req("spec")?, svc.base_seed, &svc.variant)?;
    let mut jobs = svc.jobs.lock().unwrap();
    let index = jobs.len();
    let id = format!("job-{index}");
    jobs.push(Arc::new(Job {
        id: id.clone(),
        cfg,
        inner: Mutex::new(JobInner {
            status: JobStatus::Queued,
            episode: 0,
            cancel: false,
            events: Vec::new(),
            outcome: None,
            error: None,
            artifact: None,
        }),
        done: Condvar::new(),
    }));
    drop(jobs);
    let mut queue = svc.queue.lock().unwrap();
    queue.push_back(index);
    svc.queue_cv.notify_one();
    drop(queue);
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("job", Json::str(id)),
        ("state", Json::str(JobStatus::Queued.to_string())),
    ]))
}

/// O(1) lookup: ids are `job-<index>` into the append-only jobs vec, so a
/// long-running service never pays a scan (under the global lock) per poll.
fn find_job(svc: &ServiceState<'_>, req: &Json) -> Result<Arc<Job>> {
    let id = req.req_str("job")?;
    let index: Option<usize> = id.strip_prefix("job-").and_then(|n| n.parse().ok());
    index
        .and_then(|i| svc.jobs.lock().unwrap().get(i).cloned())
        .ok_or_else(|| anyhow::anyhow!("unknown job '{id}'"))
}

fn op_status(svc: &ServiceState<'_>, req: &Json) -> Result<Json> {
    let job = find_job(svc, req)?;
    let st = job.inner.lock().unwrap();
    let mut fields = vec![
        ("ok", Json::Bool(true)),
        ("job", Json::str(job.id.clone())),
        ("state", Json::str(st.status.to_string())),
        ("episode", Json::num(st.episode as f64)),
        ("episodes", Json::num(job.cfg.episodes as f64)),
    ];
    if let Some(e) = &st.error {
        fields.push(("error", Json::str(e.clone())));
    }
    Ok(Json::obj(fields))
}

fn op_events(svc: &ServiceState<'_>, req: &Json) -> Result<Json> {
    let job = find_job(svc, req)?;
    let since = req.get("since").and_then(Json::as_usize).unwrap_or(0);
    let st = job.inner.lock().unwrap();
    let from = since.min(st.events.len());
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("job", Json::str(job.id.clone())),
        ("events", Json::Arr(st.events[from..].to_vec())),
        ("next", Json::num(st.events.len() as f64)),
    ]))
}

fn op_result(svc: &ServiceState<'_>, req: &Json) -> Result<Json> {
    let job = find_job(svc, req)?;
    let wait = req.get("wait").and_then(Json::as_bool).unwrap_or(false);
    let mut st = job.inner.lock().unwrap();
    if wait {
        while !st.status.is_terminal() {
            st = job.done.wait(st).unwrap();
        }
    }
    let mut fields = vec![
        ("ok", Json::Bool(true)),
        ("job", Json::str(job.id.clone())),
        ("state", Json::str(st.status.to_string())),
    ];
    if let Some(outcome) = &st.outcome {
        fields.push(("outcome", outcome.to_json()));
        fields.push(("policy", outcome.best_policy.to_json()));
    }
    if let Some(path) = &st.artifact {
        fields.push(("artifact", Json::str(path.display().to_string())));
    }
    if let Some(e) = &st.error {
        fields.push(("error", Json::str(e.clone())));
    }
    Ok(Json::obj(fields))
}

fn op_cancel(svc: &ServiceState<'_>, req: &Json) -> Result<Json> {
    let job = find_job(svc, req)?;
    let state = {
        let mut st = job.inner.lock().unwrap();
        st.cancel = true;
        if st.status == JobStatus::Queued {
            // never reached a worker: terminal immediately
            st.status = JobStatus::Cancelled;
            job.done.notify_all();
        }
        st.status
    };
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("job", Json::str(job.id.clone())),
        ("state", Json::str(state.to_string())),
    ]))
}

/// Release a terminal job's event log and outcome (the status line
/// survives).  A serve process is long-running and jobs are append-only,
/// so clients that fetched what they need bound the service's memory by
/// forgetting — without this every outcome and event stream would be
/// retained for the process lifetime.
fn op_forget(svc: &ServiceState<'_>, req: &Json) -> Result<Json> {
    let job = find_job(svc, req)?;
    let mut st = job.inner.lock().unwrap();
    anyhow::ensure!(
        st.status.is_terminal(),
        "job '{}' is {} — only finished jobs can be forgotten",
        job.id,
        st.status
    );
    st.events = Vec::new();
    st.outcome = None;
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("job", Json::str(job.id.clone())),
        ("state", Json::str(st.status.to_string())),
    ]))
}

fn op_list(svc: &ServiceState<'_>) -> Result<Json> {
    let jobs = svc.jobs.lock().unwrap();
    let rows = jobs
        .iter()
        .map(|job| {
            let st = job.inner.lock().unwrap();
            Json::obj(vec![
                ("job", Json::str(job.id.clone())),
                ("agent", Json::str(job.cfg.agent.to_string())),
                ("target", Json::num(job.cfg.target)),
                ("state", Json::str(st.status.to_string())),
                ("episode", Json::num(st.episode as f64)),
                ("episodes", Json::num(job.cfg.episodes as f64)),
            ])
        })
        .collect();
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("protocol", Json::num(SERVE_PROTOCOL_VERSION as f64)),
        ("jobs", Json::Arr(rows)),
    ]))
}

/// Pull jobs off the queue until shutdown is flagged *and* the queue is
/// empty — submitted work always drains, even when the client hangs up
/// right after submitting.  Idle workers park on the queue condvar (no
/// polling); submit and shutdown wake them.
fn worker_loop(svc: &ServiceState<'_>) {
    let mut queue = svc.queue.lock().unwrap();
    loop {
        if let Some(index) = queue.pop_front() {
            let job = svc.jobs.lock().unwrap()[index].clone();
            drop(queue);
            run_job(svc, &job);
            queue = svc.queue.lock().unwrap();
            continue;
        }
        if svc.shutdown.load(Ordering::SeqCst) {
            return;
        }
        queue = svc.queue_cv.wait(queue).unwrap();
    }
}

/// Drive one job start to finish on this worker thread.
fn run_job(svc: &ServiceState<'_>, job: &Arc<Job>) {
    {
        let mut st = job.inner.lock().unwrap();
        if st.status.is_terminal() {
            return; // cancelled while queued
        }
        if st.cancel {
            st.status = JobStatus::Cancelled;
            drop(st);
            job.done.notify_all();
            return;
        }
        st.status = JobStatus::Running;
    }
    log::info!("serve: {} started ({} c={})", job.id, job.cfg.agent, job.cfg.target);
    match drive_job(svc, job) {
        Ok(Some((outcome, artifact))) => job.terminal_transition(|st| {
            st.outcome = Some(outcome);
            st.artifact = artifact;
            st.status = JobStatus::Done;
        }),
        Ok(None) => job.terminal_transition(|st| st.status = JobStatus::Cancelled),
        Err(e) => {
            log::warn!("serve: {} failed: {e:#}", job.id);
            job.terminal_transition(|st| {
                st.error = Some(format!("{e:#}"));
                st.status = JobStatus::Failed;
            });
        }
    }
}

/// The worker-side search: a driver run episode by episode, events teed
/// into the job log, cancellation honored between episodes.  Returns
/// `Ok(None)` when cancelled.
fn drive_job(
    svc: &ServiceState<'_>,
    job: &Arc<Job>,
) -> Result<Option<(SearchOutcome, Option<PathBuf>)>> {
    let evaluator = SimEvaluator::new(svc.ir);
    // same per-search seed split as Session::search / sweep workers
    let mut provider = svc.factory.provider(job.cfg.seed ^ 0x5117, svc.ir)?;
    let mapper = mapper_for(job.cfg.agent);
    let mut driver = SearchBuilder::from_config(job.cfg.clone()).build(
        svc.ir,
        svc.sens,
        &evaluator,
        provider.as_mut(),
        mapper.as_ref(),
    )?;
    let sink = job.clone();
    driver.add_observer(move |event: &SearchEvent| {
        let mut st = sink.inner.lock().unwrap();
        if let SearchEvent::EpisodeFinished(s) = event {
            st.episode = s.episode + 1;
        }
        st.events.push(event.to_json());
    });
    let mut cancelled_at = None;
    loop {
        // completion wins over a cancel landing during the final episode:
        // "cancel at the next episode boundary" has no boundary left, and
        // the event stream has already announced `finished`
        if driver.is_done() {
            break;
        }
        if job.inner.lock().unwrap().cancel {
            cancelled_at = Some(driver.episode());
            break;
        }
        if driver.run_episode()?.is_none() {
            break;
        }
    }
    let outcome = if cancelled_at.is_none() {
        Some(driver.outcome()?)
    } else {
        None
    };
    drop(driver);
    // persist even on the cancel path: measured/hybrid backends already
    // paid for their kernel measurements, the next job should reuse them
    provider.persist()?;
    let Some(outcome) = outcome else {
        log::info!(
            "serve: {} cancelled at episode {}",
            job.id,
            cancelled_at.unwrap_or(0)
        );
        return Ok(None);
    };
    let artifact = match &svc.results_dir {
        None => None,
        Some(dir) => {
            let record = ExperimentRecord {
                name: format!("serve_{}_{}", svc.variant, job.id),
                config: job.cfg.clone(),
                outcome: outcome.clone(),
            };
            Some(record.save(svc.ir, dir)?)
        }
    };
    log::info!(
        "serve: {} done (best reward {:+.4}, rel.lat {:.1}%)",
        job.id,
        outcome.best.reward,
        outcome.relative_latency() * 100.0
    );
    Ok(Some((outcome, artifact)))
}
