//! Experiment result records (results/*.json) and table/figure printers.

use anyhow::Result;

use crate::compress::DiscretePolicy;
use crate::model::ModelIr;
use crate::search::{SearchConfig, SearchOutcome};
use crate::util::json::Json;

/// A persisted experiment result: config + outcome (+ policy detail).
pub struct ExperimentRecord {
    /// Record name (also the file stem under results/).
    pub name: String,
    /// The search configuration that produced the outcome.
    pub config: SearchConfig,
    /// The search result.
    pub outcome: SearchOutcome,
}

impl ExperimentRecord {
    /// JSON form (the results/*.json layout).
    pub fn to_json(&self, ir: &ModelIr) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("config", self.config.to_json()),
            ("outcome", self.outcome.to_json()),
            ("policy", policy_json(ir, &self.outcome.best_policy)),
        ])
    }

    /// Write the record to `dir/<name>.json`; returns the path.
    pub fn save(&self, ir: &ModelIr, dir: &std::path::Path) -> Result<std::path::PathBuf> {
        let path = dir.join(format!("{}.json", self.name));
        self.to_json(ir).write_file(&path)?;
        Ok(path)
    }

    /// One row of Table 1: method, c, MACs, BOPs, latency, accuracy.
    pub fn table1_row(&self) -> String {
        format!(
            "{:16} {:>4.2} {:>10.3e} {:>10.3e} {:>8.2} ms {:>7.2} % {:>7.1} %",
            self.config.agent,
            self.config.target,
            self.outcome.best.macs as f64,
            self.outcome.best.bops as f64,
            self.outcome.best.latency_s * 1e3,
            self.outcome.best.accuracy * 100.0,
            self.outcome.relative_latency() * 100.0,
        )
    }
}

/// Per-layer policy detail (Fig 3/5/7 bar-chart data).
pub fn policy_json(ir: &ModelIr, p: &DiscretePolicy) -> Json {
    Json::Arr(
        ir.layers
            .iter()
            .map(|l| {
                let cmp = &p.layers[l.index];
                let (wb, ab) = cmp.quant.bits();
                Json::obj(vec![
                    ("layer", Json::str(l.name.clone())),
                    ("channels", Json::num(cmp.kept_channels as f64)),
                    ("channels_orig", Json::num(l.cout as f64)),
                    ("quant", Json::str(cmp.quant.label())),
                    ("w_bits", Json::num(wb as f64)),
                    ("a_bits", Json::num(ab as f64)),
                    ("prunable", Json::Bool(l.prunable)),
                ])
            })
            .collect(),
    )
}

/// Printable per-layer policy table (the textual Figure 3).
pub fn policy_report(ir: &ModelIr, p: &DiscretePolicy) -> String {
    let mut s = format!(
        "{:14} {:>9} {:>6} {:>12}  bar (remaining channels)\n",
        "layer", "channels", "grp", "quant"
    );
    for l in &ir.layers {
        let cmp = &p.layers[l.index];
        let frac = cmp.kept_channels as f64 / l.cout as f64;
        let bar: String = "#".repeat((frac * 24.0).round() as usize);
        let grp = if l.group >= 0 {
            format!("g{}", l.group)
        } else if l.prunable {
            "-".into()
        } else {
            "fix".into()
        };
        s.push_str(&format!(
            "{:14} {:>4}/{:<4} {:>6} {:>12}  {}\n",
            l.name,
            cmp.kept_channels,
            l.cout,
            grp,
            cmp.quant.label(),
            bar
        ));
    }
    s
}

/// Header matching `ExperimentRecord::table1_row`.
pub fn table1_header() -> String {
    format!(
        "{:16} {:>4} {:>10} {:>10} {:>11} {:>9} {:>9}\n{}",
        "method",
        "c",
        "MACs",
        "BOPs",
        "latency",
        "accuracy",
        "rel.lat",
        "-".repeat(78)
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::AgentKind;
    use crate::model::ir::test_fixtures::tiny_meta;
    use crate::model::ModelIr;
    use crate::search::EpisodeSummary;

    fn outcome(ir: &ModelIr) -> SearchOutcome {
        let p = DiscretePolicy::reference(ir);
        SearchOutcome {
            best_policy: p.clone(),
            best: EpisodeSummary {
                episode: 3,
                reward: 0.8,
                accuracy: 0.91,
                latency_s: 0.004,
                macs: p.macs(ir),
                bops: p.bops(ir),
            },
            history: vec![],
            base_latency_s: 0.01,
            base_accuracy: 0.95,
            latency_backend: "sim".into(),
        }
    }

    #[test]
    fn record_roundtrips_to_json() {
        let ir = ModelIr::from_meta(&tiny_meta()).unwrap();
        let rec = ExperimentRecord {
            name: "test_record".into(),
            config: SearchConfig::new(AgentKind::Joint, 0.3),
            outcome: outcome(&ir),
        };
        let j = rec.to_json(&ir);
        assert_eq!(j.req_str("name").unwrap(), "test_record");
        let policy = j.req_arr("policy").unwrap();
        assert_eq!(policy.len(), ir.layers.len());
        assert!(rec.table1_row().contains("joint"));
    }

    #[test]
    fn policy_report_readable() {
        let ir = ModelIr::from_meta(&tiny_meta()).unwrap();
        let p = DiscretePolicy::reference(&ir);
        let rep = policy_report(&ir, &p);
        assert!(rep.contains("stem"));
        assert!(rep.contains("FP32"));
        assert!(rep.lines().count() >= ir.layers.len());
    }
}
