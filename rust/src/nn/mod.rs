//! From-scratch neural nets for the DDPG agents.
//!
//! The paper's actor/critic networks are 2-hidden-layer MLPs (400, 300
//! units, ReLU hidden activations; Sigmoid output for the actor, linear for
//! the critic), optimized with Adam.  This module implements exactly that
//! with hand-derived backprop (verified against finite differences in the
//! tests) plus Polyak soft target updates.

/// Adam optimizer over an `Mlp`.
pub mod adam;
/// MLP with manual backprop and reusable training workspaces.
pub mod mlp;

pub use adam::Adam;
pub use mlp::{Activation, Mlp, MlpGrads, TrainWorkspace};
