//! MLP with manual backprop (Linear -> act -> Linear -> act -> ... -> out).

use crate::tensor::Mat;
use crate::util::rng::Pcg64;

/// Elementwise activation functions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    /// max(0, x).
    Relu,
    /// 1 / (1 + e^-x).
    Sigmoid,
    /// tanh(x).
    Tanh,
    /// Identity (output layers).
    Linear,
}

impl Activation {
    /// Stable serialization tag (checkpoint format).
    pub fn tag(self) -> &'static str {
        match self {
            Activation::Relu => "relu",
            Activation::Sigmoid => "sigmoid",
            Activation::Tanh => "tanh",
            Activation::Linear => "linear",
        }
    }

    /// Inverse of [`Activation::tag`].
    pub fn from_tag(s: &str) -> anyhow::Result<Self> {
        match s {
            "relu" => Ok(Activation::Relu),
            "sigmoid" => Ok(Activation::Sigmoid),
            "tanh" => Ok(Activation::Tanh),
            "linear" => Ok(Activation::Linear),
            other => anyhow::bail!("unknown activation tag '{other}'"),
        }
    }

    #[inline]
    fn apply(self, x: f32) -> f32 {
        match self {
            Activation::Relu => x.max(0.0),
            Activation::Sigmoid => 1.0 / (1.0 + (-x).exp()),
            Activation::Tanh => x.tanh(),
            Activation::Linear => x,
        }
    }

    /// Derivative expressed in terms of the *activated output* y.
    #[inline]
    fn dydx_from_y(self, y: f32) -> f32 {
        match self {
            Activation::Relu => {
                if y > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            Activation::Sigmoid => y * (1.0 - y),
            Activation::Tanh => 1.0 - y * y,
            Activation::Linear => 1.0,
        }
    }
}

/// One dense layer: y = act(x W + b), W is [in, out] row-major.
#[derive(Clone, Debug)]
pub struct Layer {
    /// Weight matrix, [in, out] row-major.
    pub w: Mat,
    /// Bias vector (length out).
    pub b: Vec<f32>,
    /// Activation applied to the affine output.
    pub act: Activation,
}

/// Multi-layer perceptron.
#[derive(Clone, Debug)]
pub struct Mlp {
    /// Dense layers, input to output.
    pub layers: Vec<Layer>,
}

/// Per-layer parameter gradients, same shapes as the parameters.
#[derive(Clone, Debug)]
pub struct MlpGrads {
    /// Weight gradients, per layer.
    pub w: Vec<Mat>,
    /// Bias gradients, per layer.
    pub b: Vec<Vec<f32>>,
}

/// Forward activations cache for backprop.
pub struct ForwardCache {
    /// activations[0] = input, activations[i+1] = output of layer i.
    pub activations: Vec<Mat>,
}

/// Reusable forward/backward buffers, keyed by (network shape, batch size).
///
/// `forward_cached_ws` / `backward_ws` run against these pre-sized buffers
/// instead of allocating fresh matrices, so a training step that reuses one
/// workspace is allocation-free at steady state (the first step at a new
/// batch shape grows the buffers; subsequent steps only overwrite them).
/// Results are bit-exact with the allocating `forward_cached` / `backward`.
pub struct TrainWorkspace {
    /// activations[0] = input copy, activations[i+1] = output of layer i.
    pub activations: Vec<Mat>,
    /// `delta[i] = dLoss/d(activations[i])` scratch, same shapes as activations.
    delta: Vec<Mat>,
    /// Parameter gradients of the most recent `backward_ws` call.
    pub grads: MlpGrads,
    batch: usize,
}

impl Default for TrainWorkspace {
    fn default() -> Self {
        Self::new()
    }
}

impl TrainWorkspace {
    /// An empty workspace (buffers grow on first use).
    pub fn new() -> Self {
        Self {
            activations: Vec::new(),
            delta: Vec::new(),
            grads: MlpGrads {
                w: Vec::new(),
                b: Vec::new(),
            },
            batch: 0,
        }
    }

    /// Size every buffer for `mlp` at batch size `batch`, reusing existing
    /// allocations whenever they are already large enough.
    fn ensure(&mut self, mlp: &Mlp, batch: usize) {
        let n = mlp.layers.len();
        self.activations.resize_with(n + 1, || Mat::zeros(0, 0));
        self.delta.resize_with(n + 1, || Mat::zeros(0, 0));
        self.grads.w.resize_with(n, || Mat::zeros(0, 0));
        self.grads.b.resize_with(n, Vec::new);
        self.activations[0].reshape_to(batch, mlp.layers[0].w.rows);
        self.delta[0].reshape_to(batch, mlp.layers[0].w.rows);
        for (i, l) in mlp.layers.iter().enumerate() {
            self.activations[i + 1].reshape_to(batch, l.w.cols);
            self.delta[i + 1].reshape_to(batch, l.w.cols);
            self.grads.w[i].reshape_to(l.w.rows, l.w.cols);
            self.grads.b[i].resize(l.b.len(), 0.0);
        }
        self.batch = batch;
    }

    /// Network output of the most recent `forward_cached_ws`.
    pub fn output(&self) -> &Mat {
        self.activations.last().expect("forward_cached_ws not run")
    }

    /// dLoss/dinput of the most recent `backward_ws`.
    pub fn input_grad(&self) -> &Mat {
        &self.delta[0]
    }

    /// (pointer, capacity) of every owned buffer — lets tests assert
    /// steady-state reuse (no reallocation across steps).
    pub fn buffer_fingerprint(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for m in self.activations.iter().chain(&self.delta).chain(&self.grads.w) {
            out.push((m.data.as_ptr() as usize, m.data.capacity()));
        }
        for b in &self.grads.b {
            out.push((b.as_ptr() as usize, b.capacity()));
        }
        out
    }
}

impl Mlp {
    /// `sizes` = [in, h1, ..., out]; `acts.len() == sizes.len() - 1`.
    /// Init: uniform fan-in (DDPG paper init) — U(-1/sqrt(fan_in), +1/sqrt(fan_in)),
    /// with the final layer at U(-3e-3, 3e-3) for stable early Q-values.
    pub fn new(sizes: &[usize], acts: &[Activation], rng: &mut Pcg64) -> Self {
        assert_eq!(acts.len(), sizes.len() - 1);
        let mut layers = Vec::new();
        for i in 0..acts.len() {
            let (fin, fout) = (sizes[i], sizes[i + 1]);
            let bound = if i + 1 == acts.len() {
                3e-3
            } else {
                1.0 / (fin as f64).sqrt()
            };
            let mut w = Mat::zeros(fin, fout);
            for x in &mut w.data {
                *x = rng.uniform(-bound, bound) as f32;
            }
            let mut b = vec![0.0f32; fout];
            for x in &mut b {
                *x = rng.uniform(-bound, bound) as f32;
            }
            layers.push(Layer { w, b, act: acts[i] });
        }
        Self { layers }
    }

    /// Input dimension of the first layer.
    pub fn input_dim(&self) -> usize {
        self.layers[0].w.rows
    }

    /// Output dimension of the last layer.
    pub fn output_dim(&self) -> usize {
        self.layers.last().unwrap().w.cols
    }

    /// Total number of trainable parameters.
    pub fn param_count(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.w.data.len() + l.b.len())
            .sum()
    }

    /// Forward for a batch [B, in] -> [B, out].
    pub fn forward(&self, x: &Mat) -> Mat {
        let mut h = x.clone();
        for layer in &self.layers {
            let mut z = h.matmul(&layer.w);
            z.add_row(&layer.b);
            z.map_inplace(|v| layer.act.apply(v));
            h = z;
        }
        h
    }

    /// Forward for a single vector.
    pub fn forward1(&self, x: &[f32]) -> Vec<f32> {
        let m = Mat::from_vec(1, x.len(), x.to_vec());
        self.forward(&m).data
    }

    /// Forward keeping the activation cache for `backward`.
    pub fn forward_cached(&self, x: &Mat) -> ForwardCache {
        let mut activations = Vec::with_capacity(self.layers.len() + 1);
        activations.push(x.clone());
        for layer in &self.layers {
            let mut z = activations.last().unwrap().matmul(&layer.w);
            z.add_row(&layer.b);
            z.map_inplace(|v| layer.act.apply(v));
            activations.push(z);
        }
        ForwardCache { activations }
    }

    /// `forward_cached` into a reusable workspace: identical math, zero
    /// allocation once `ws` has seen this (network, batch) shape.
    pub fn forward_cached_ws(&self, x: &Mat, ws: &mut TrainWorkspace) {
        assert_eq!(x.cols, self.input_dim(), "input width");
        ws.ensure(self, x.rows);
        ws.activations[0].copy_from_mat(x);
        for (i, layer) in self.layers.iter().enumerate() {
            let (prev, rest) = ws.activations.split_at_mut(i + 1);
            let z = &mut rest[0];
            prev[i].matmul_into(&layer.w, z);
            z.add_row(&layer.b);
            z.map_inplace(|v| layer.act.apply(v));
        }
    }

    /// `backward` into a reusable workspace: parameter grads land in
    /// `ws.grads`, dLoss/dinput in `ws.input_grad()`.  Must follow a
    /// `forward_cached_ws` on the same workspace.
    pub fn backward_ws(&self, ws: &mut TrainWorkspace, dout: &Mat) {
        let n = self.layers.len();
        assert_eq!(ws.batch, dout.rows, "workspace batch");
        assert_eq!(dout.cols, self.output_dim(), "output width");
        ws.delta[n].copy_from_mat(dout);
        for (i, layer) in self.layers.iter().enumerate().rev() {
            let (dprev, drest) = ws.delta.split_at_mut(i + 1);
            let dz = &mut drest[0];
            // dL/dz = dL/dy * act'(z) (expressed via y)
            let y = &ws.activations[i + 1];
            for (v, &yv) in dz.data.iter_mut().zip(&y.data) {
                *v *= layer.act.dydx_from_y(yv);
            }
            ws.activations[i].t_matmul_into(dz, &mut ws.grads.w[i]); // [in, out]
            dz.col_sum_into(&mut ws.grads.b[i]);
            dz.matmul_t_into(&layer.w, &mut dprev[i]); // [B, in]
        }
    }

    /// Backprop `dloss/doutput` through the net.
    /// Returns (parameter grads, dloss/dinput).
    pub fn backward(&self, cache: &ForwardCache, dout: &Mat) -> (MlpGrads, Mat) {
        let n = self.layers.len();
        let mut gw: Vec<Mat> = Vec::with_capacity(n);
        let mut gb: Vec<Vec<f32>> = Vec::with_capacity(n);
        // walk backwards
        let mut delta = dout.clone();
        let mut gw_rev = Vec::with_capacity(n);
        let mut gb_rev = Vec::with_capacity(n);
        for (i, layer) in self.layers.iter().enumerate().rev() {
            let y = &cache.activations[i + 1];
            // dL/dz = dL/dy * act'(z) (expressed via y)
            let mut dz = delta;
            for (v, &yv) in dz.data.iter_mut().zip(&y.data) {
                *v *= layer.act.dydx_from_y(yv);
            }
            let x = &cache.activations[i];
            gw_rev.push(x.t_matmul(&dz)); // [in, out]
            gb_rev.push(dz.col_sum());
            delta = dz.matmul_t(&layer.w); // [B, in]
        }
        for _ in 0..n {
            gw.push(gw_rev.pop().unwrap());
            gb.push(gb_rev.pop().unwrap());
        }
        (MlpGrads { w: gw, b: gb }, delta)
    }

    /// Polyak soft update: self = tau * src + (1 - tau) * self.
    pub fn soft_update_from(&mut self, src: &Mlp, tau: f32) {
        assert_eq!(self.layers.len(), src.layers.len());
        for (dst, s) in self.layers.iter_mut().zip(&src.layers) {
            for (d, &sv) in dst.w.data.iter_mut().zip(&s.w.data) {
                *d += tau * (sv - *d);
            }
            for (d, &sv) in dst.b.iter_mut().zip(&s.b) {
                *d += tau * (sv - *d);
            }
        }
    }

    /// Hard copy of parameters (bit-exact, unlike soft_update with tau=1).
    pub fn copy_from(&mut self, src: &Mlp) {
        assert_eq!(self.layers.len(), src.layers.len());
        for (dst, s) in self.layers.iter_mut().zip(&src.layers) {
            dst.w.data.copy_from_slice(&s.w.data);
            dst.b.copy_from_slice(&s.b);
        }
    }

    /// Serialize every parameter (checkpoint format); round-trips
    /// bit-exactly through [`Mlp::from_json`] — f32 weights embed exactly
    /// into the JSON f64 number path.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![(
            "layers",
            Json::Arr(
                self.layers
                    .iter()
                    .map(|l| {
                        Json::obj(vec![
                            ("in", Json::num(l.w.rows as f64)),
                            ("out", Json::num(l.w.cols as f64)),
                            ("act", Json::str(l.act.tag())),
                            ("w", Json::arr_f32(&l.w.data)),
                            ("b", Json::arr_f32(&l.b)),
                        ])
                    })
                    .collect(),
            ),
        )])
    }

    /// Rebuild a network serialized by [`Mlp::to_json`].
    pub fn from_json(j: &crate::util::json::Json) -> anyhow::Result<Self> {
        let mut layers = Vec::new();
        for e in j.req_arr("layers")? {
            let rows = e.req_usize("in")?;
            let cols = e.req_usize("out")?;
            let w = e.req_f32s("w")?;
            let b = e.req_f32s("b")?;
            anyhow::ensure!(w.len() == rows * cols, "mlp layer weight shape mismatch");
            anyhow::ensure!(b.len() == cols, "mlp layer bias shape mismatch");
            // the chain must compose: a corrupted checkpoint fails here,
            // not in a matmul shape assert on the first forward pass
            if let Some(prev) = layers.last() {
                anyhow::ensure!(
                    rows == prev.w.cols,
                    "mlp layer chain mismatch (in {} vs previous out {})",
                    rows,
                    prev.w.cols
                );
            }
            layers.push(Layer {
                w: Mat::from_vec(rows, cols, w),
                b,
                act: Activation::from_tag(e.req_str("act")?)?,
            });
        }
        anyhow::ensure!(!layers.is_empty(), "mlp checkpoint has no layers");
        Ok(Self { layers })
    }

    /// Global L2 gradient-norm clipping; returns the pre-clip norm.
    pub fn clip_grads(grads: &mut MlpGrads, max_norm: f32) -> f32 {
        let mut sq = 0.0f64;
        for g in &grads.w {
            for &x in &g.data {
                sq += (x as f64) * (x as f64);
            }
        }
        for g in &grads.b {
            for &x in g {
                sq += (x as f64) * (x as f64);
            }
        }
        let norm = sq.sqrt() as f32;
        if norm > max_norm && norm > 0.0 {
            let s = max_norm / norm;
            for g in &mut grads.w {
                g.scale(s);
            }
            for g in &mut grads.b {
                for x in g {
                    *x *= s;
                }
            }
        }
        norm
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_mlp(seed: u64) -> Mlp {
        let mut rng = Pcg64::new(seed);
        Mlp::new(
            &[4, 8, 6, 2],
            &[Activation::Relu, Activation::Tanh, Activation::Sigmoid],
            &mut rng,
        )
    }

    #[test]
    fn forward_shapes() {
        let mlp = tiny_mlp(1);
        let x = Mat::zeros(5, 4);
        let y = mlp.forward(&x);
        assert_eq!((y.rows, y.cols), (5, 2));
        assert_eq!(mlp.input_dim(), 4);
        assert_eq!(mlp.output_dim(), 2);
    }

    #[test]
    fn sigmoid_output_bounded() {
        let mlp = tiny_mlp(2);
        let mut rng = Pcg64::new(9);
        for _ in 0..50 {
            let x: Vec<f32> = (0..4).map(|_| rng.normal_scaled(0.0, 10.0) as f32).collect();
            for y in mlp.forward1(&x) {
                assert!((0.0..=1.0).contains(&y));
            }
        }
    }

    /// The core correctness test: analytic gradients vs finite differences.
    #[test]
    fn gradients_match_finite_differences() {
        let mut mlp = tiny_mlp(3);
        let mut rng = Pcg64::new(4);
        let x = {
            let mut m = Mat::zeros(3, 4);
            for v in &mut m.data {
                *v = rng.normal() as f32;
            }
            m
        };
        // loss = sum(y^2)/2 -> dL/dy = y
        let cache = mlp.forward_cached(&x);
        let y = cache.activations.last().unwrap().clone();
        let (grads, dx) = mlp.backward(&cache, &y);

        let loss = |mlp: &Mlp, x: &Mat| -> f64 {
            let y = mlp.forward(x);
            y.data.iter().map(|&v| 0.5 * (v as f64) * (v as f64)).sum()
        };
        let eps = 1e-3f32;

        // check a sample of weight grads in every layer
        for li in 0..mlp.layers.len() {
            let n = mlp.layers[li].w.data.len();
            for &pi in &[0usize, n / 2, n - 1] {
                let orig = mlp.layers[li].w.data[pi];
                mlp.layers[li].w.data[pi] = orig + eps;
                let lp = loss(&mlp, &x);
                mlp.layers[li].w.data[pi] = orig - eps;
                let lm = loss(&mlp, &x);
                mlp.layers[li].w.data[pi] = orig;
                let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
                let an = grads.w[li].data[pi];
                assert!(
                    (fd - an).abs() < 2e-2 * (1.0 + fd.abs().max(an.abs())),
                    "layer {li} w[{pi}]: fd={fd} analytic={an}"
                );
            }
            // bias grads
            let orig = mlp.layers[li].b[0];
            mlp.layers[li].b[0] = orig + eps;
            let lp = loss(&mlp, &x);
            mlp.layers[li].b[0] = orig - eps;
            let lm = loss(&mlp, &x);
            mlp.layers[li].b[0] = orig;
            let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
            let an = grads.b[li][0];
            assert!(
                (fd - an).abs() < 2e-2 * (1.0 + fd.abs().max(an.abs())),
                "layer {li} b[0]: fd={fd} analytic={an}"
            );
        }

        // input gradient
        let mut x2 = x.clone();
        let orig = x2.data[1];
        x2.data[1] = orig + eps;
        let lp = loss(&mlp, &x2);
        x2.data[1] = orig - eps;
        let lm = loss(&mlp, &x2);
        let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
        assert!((fd - dx.data[1]).abs() < 2e-2 * (1.0 + fd.abs()));
    }

    /// The workspace paths must be bit-exact with the allocating paths —
    /// they share the same kernels and the same accumulation order.
    #[test]
    fn workspace_paths_bit_exact_with_allocating_paths() {
        let mlp = tiny_mlp(11);
        let mut rng = Pcg64::new(12);
        let mut x = Mat::zeros(5, 4);
        for v in &mut x.data {
            *v = rng.normal() as f32;
        }
        let cache = mlp.forward_cached(&x);
        let y = cache.activations.last().unwrap().clone();
        let (grads, dx) = mlp.backward(&cache, &y);

        let mut ws = TrainWorkspace::new();
        mlp.forward_cached_ws(&x, &mut ws);
        assert_eq!(ws.output(), &y);
        for (a, b) in ws.activations.iter().zip(&cache.activations) {
            assert_eq!(a, b);
        }
        mlp.backward_ws(&mut ws, &y);
        for (a, b) in ws.grads.w.iter().zip(&grads.w) {
            assert_eq!(a, b);
        }
        for (a, b) in ws.grads.b.iter().zip(&grads.b) {
            assert_eq!(a, b);
        }
        assert_eq!(ws.input_grad(), &dx);

        // second pass on the same workspace: buffers are reused, not regrown
        let fp = ws.buffer_fingerprint();
        mlp.forward_cached_ws(&x, &mut ws);
        mlp.backward_ws(&mut ws, &y);
        assert_eq!(fp, ws.buffer_fingerprint(), "workspace reallocated");
    }

    #[test]
    fn json_roundtrip_is_bit_exact() {
        use crate::util::json::Json;
        let mlp = tiny_mlp(21);
        let back = Mlp::from_json(&Json::parse(&mlp.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back.layers.len(), mlp.layers.len());
        for (a, b) in back.layers.iter().zip(&mlp.layers) {
            assert_eq!(a.act, b.act);
            assert_eq!((a.w.rows, a.w.cols), (b.w.rows, b.w.cols));
            for (x, y) in a.w.data.iter().zip(&b.w.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            for (x, y) in a.b.iter().zip(&b.b) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn soft_update_interpolates() {
        let a = tiny_mlp(5);
        let mut b = tiny_mlp(6);
        let orig_b = b.layers[0].w.data[0];
        let av = a.layers[0].w.data[0];
        b.soft_update_from(&a, 0.25);
        let expect = orig_b + 0.25 * (av - orig_b);
        assert!((b.layers[0].w.data[0] - expect).abs() < 1e-6);
        // tau=1 copies exactly
        b.copy_from(&a);
        assert_eq!(b.layers[0].w.data, a.layers[0].w.data);
    }

    #[test]
    fn clip_grads_bounds_norm() {
        let mlp = tiny_mlp(7);
        let x = Mat::from_vec(1, 4, vec![10.0, -10.0, 5.0, 3.0]);
        let cache = mlp.forward_cached(&x);
        let dout = Mat::from_vec(1, 2, vec![100.0, -100.0]);
        let (mut grads, _) = mlp.backward(&cache, &dout);
        let pre = Mlp::clip_grads(&mut grads, 1.0);
        assert!(pre > 0.0);
        let mut sq = 0.0f64;
        for g in &grads.w {
            sq += g.data.iter().map(|&v| (v as f64).powi(2)).sum::<f64>();
        }
        for g in &grads.b {
            sq += g.iter().map(|&v| (v as f64).powi(2)).sum::<f64>();
        }
        assert!(sq.sqrt() <= 1.0 + 1e-4);
    }

    #[test]
    fn training_reduces_regression_loss() {
        // sanity: MLP + manual grads can fit a tiny function with plain SGD
        let mut rng = Pcg64::new(8);
        let mut mlp = Mlp::new(
            &[2, 16, 1],
            &[Activation::Relu, Activation::Linear],
            &mut rng,
        );
        let xs: Vec<[f32; 2]> = (0..64)
            .map(|_| [rng.uniform(-1.0, 1.0) as f32, rng.uniform(-1.0, 1.0) as f32])
            .collect();
        let target = |a: f32, b: f32| a * 0.5 - b * 0.25 + 0.1;
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..300 {
            let x = Mat::from_rows(&xs.iter().map(|p| p.to_vec()).collect::<Vec<_>>());
            let cache = mlp.forward_cached(&x);
            let y = cache.activations.last().unwrap();
            let mut dout = Mat::zeros(y.rows, 1);
            let mut loss = 0.0f32;
            for i in 0..y.rows {
                let t = target(xs[i][0], xs[i][1]);
                let d = y.at(i, 0) - t;
                loss += d * d;
                *dout.at_mut(i, 0) = 2.0 * d / y.rows as f32;
            }
            loss /= y.rows as f32;
            first.get_or_insert(loss);
            last = loss;
            let (grads, _) = mlp.backward(&cache, &dout);
            for (layer, (gw, gb)) in mlp.layers.iter_mut().zip(grads.w.iter().zip(&grads.b)) {
                for (w, &g) in layer.w.data.iter_mut().zip(&gw.data) {
                    *w -= 0.05 * g;
                }
                for (b, &g) in layer.b.iter_mut().zip(gb) {
                    *b -= 0.05 * g;
                }
            }
        }
        assert!(last < 0.05 * first.unwrap(), "first={first:?} last={last}");
    }
}
