//! Adam optimizer (Kingma & Ba 2015) over an `Mlp`, with the paper's
//! hyperparameters as defaults: beta1=0.9, beta2=0.999 (actor lr 1e-4,
//! critic lr 1e-3 are passed by the agents).

use super::mlp::{Mlp, MlpGrads};
use crate::tensor::Mat;

/// Adam state (first/second moments) for one `Mlp`.
#[derive(Clone, Debug)]
pub struct Adam {
    /// Learning rate.
    pub lr: f32,
    /// First-moment decay.
    pub beta1: f32,
    /// Second-moment decay.
    pub beta2: f32,
    /// Denominator fuzz.
    pub eps: f32,
    t: u64,
    m_w: Vec<Mat>,
    v_w: Vec<Mat>,
    m_b: Vec<Vec<f32>>,
    v_b: Vec<Vec<f32>>,
}

impl Adam {
    /// Zero-initialized optimizer state shaped like `model`.
    pub fn new(model: &Mlp, lr: f32) -> Self {
        let m_w = model
            .layers
            .iter()
            .map(|l| Mat::zeros(l.w.rows, l.w.cols))
            .collect::<Vec<_>>();
        let v_w = m_w.clone();
        let m_b: Vec<Vec<f32>> = model.layers.iter().map(|l| vec![0.0; l.b.len()]).collect();
        let v_b = m_b.clone();
        Self {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            t: 0,
            m_w,
            v_w,
            m_b,
            v_b,
        }
    }

    /// Number of steps applied so far.
    pub fn steps(&self) -> u64 {
        self.t
    }

    /// Serialize the full optimizer state — hyper-parameters, step counter,
    /// and both moment estimates (checkpoint format).  Moment tensors are
    /// stored flat; their shapes are recovered from the paired model in
    /// [`Adam::from_json`].
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mats = |ms: &[Mat]| Json::Arr(ms.iter().map(|m| Json::arr_f32(&m.data)).collect());
        let vecs = |vs: &[Vec<f32>]| Json::Arr(vs.iter().map(|v| Json::arr_f32(v)).collect());
        Json::obj(vec![
            ("lr", Json::num(self.lr as f64)),
            ("beta1", Json::num(self.beta1 as f64)),
            ("beta2", Json::num(self.beta2 as f64)),
            ("eps", Json::num(self.eps as f64)),
            ("t", Json::num(self.t as f64)),
            ("m_w", mats(&self.m_w)),
            ("v_w", mats(&self.v_w)),
            ("m_b", vecs(&self.m_b)),
            ("v_b", vecs(&self.v_b)),
        ])
    }

    /// Rebuild optimizer state serialized by [`Adam::to_json`], shaped for
    /// `model` (the same network the state was saved against).
    pub fn from_json(j: &crate::util::json::Json, model: &Mlp) -> anyhow::Result<Self> {
        use super::mlp::Layer;
        // one flat-f32 buffer per layer, shape-checked against `expect(l)`
        let read = |key: &str, expect: fn(&Layer) -> usize| -> anyhow::Result<Vec<Vec<f32>>> {
            let arr = j.req_arr(key)?;
            anyhow::ensure!(arr.len() == model.layers.len(), "adam '{key}' layer count mismatch");
            arr.iter()
                .zip(&model.layers)
                .map(|(e, l)| {
                    let data = e
                        .f32s()
                        .map_err(|err| anyhow::anyhow!("adam '{key}': {err}"))?;
                    anyhow::ensure!(data.len() == expect(l), "adam '{key}' shape mismatch");
                    Ok(data)
                })
                .collect()
        };
        let to_mats = |flats: Vec<Vec<f32>>| -> Vec<Mat> {
            flats
                .into_iter()
                .zip(&model.layers)
                .map(|(data, l)| Mat::from_vec(l.w.rows, l.w.cols, data))
                .collect()
        };
        let weight_len = |l: &Layer| l.w.rows * l.w.cols;
        let bias_len = |l: &Layer| l.b.len();
        Ok(Self {
            lr: j.req_f64("lr")? as f32,
            beta1: j.req_f64("beta1")? as f32,
            beta2: j.req_f64("beta2")? as f32,
            eps: j.req_f64("eps")? as f32,
            t: j.req_f64("t")? as u64,
            m_w: to_mats(read("m_w", weight_len)?),
            v_w: to_mats(read("v_w", weight_len)?),
            m_b: read("m_b", bias_len)?,
            v_b: read("v_b", bias_len)?,
        })
    }

    /// Apply one Adam step of `grads` to `model` (grads = dLoss/dparam;
    /// descends).
    pub fn step(&mut self, model: &mut Mlp, grads: &MlpGrads) {
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for li in 0..model.layers.len() {
            let layer = &mut model.layers[li];
            let (mw, vw) = (&mut self.m_w[li], &mut self.v_w[li]);
            for i in 0..layer.w.data.len() {
                let g = grads.w[li].data[i];
                mw.data[i] = self.beta1 * mw.data[i] + (1.0 - self.beta1) * g;
                vw.data[i] = self.beta2 * vw.data[i] + (1.0 - self.beta2) * g * g;
                let mh = mw.data[i] / b1t;
                let vh = vw.data[i] / b2t;
                layer.w.data[i] -= self.lr * mh / (vh.sqrt() + self.eps);
            }
            let (mb, vb) = (&mut self.m_b[li], &mut self.v_b[li]);
            for i in 0..layer.b.len() {
                let g = grads.b[li][i];
                mb[i] = self.beta1 * mb[i] + (1.0 - self.beta1) * g;
                vb[i] = self.beta2 * vb[i] + (1.0 - self.beta2) * g * g;
                let mh = mb[i] / b1t;
                let vh = vb[i] / b2t;
                layer.b[i] -= self.lr * mh / (vh.sqrt() + self.eps);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::mlp::Activation;
    use crate::util::rng::Pcg64;

    #[test]
    fn adam_fits_faster_than_no_update() {
        let mut rng = Pcg64::new(1);
        let mut mlp = Mlp::new(&[1, 8, 1], &[Activation::Tanh, Activation::Linear], &mut rng);
        let mut opt = Adam::new(&mlp, 1e-2);
        let xs: Vec<f32> = (0..32).map(|i| i as f32 / 16.0 - 1.0).collect();
        let mut losses = Vec::new();
        for _ in 0..400 {
            let x = Mat::from_vec(32, 1, xs.clone());
            let cache = mlp.forward_cached(&x);
            let y = cache.activations.last().unwrap();
            let mut dout = Mat::zeros(32, 1);
            let mut loss = 0.0f32;
            for i in 0..32 {
                let t = (2.0 * xs[i]).sin();
                let d = y.at(i, 0) - t;
                loss += d * d / 32.0;
                *dout.at_mut(i, 0) = 2.0 * d / 32.0;
            }
            losses.push(loss);
            let (grads, _) = mlp.backward(&cache, &dout);
            opt.step(&mut mlp, &grads);
        }
        assert!(losses[399] < 0.02, "final loss {}", losses[399]);
        assert!(losses[399] < 0.05 * losses[0]);
        assert_eq!(opt.steps(), 400);
    }

    #[test]
    fn json_roundtrip_preserves_trajectory() {
        use crate::util::json::Json;
        // two optimizers that share state mid-training must take identical
        // future steps — the checkpoint/resume contract
        let mut rng = Pcg64::new(3);
        let mut mlp = Mlp::new(&[2, 6, 1], &[Activation::Relu, Activation::Linear], &mut rng);
        let mut opt = Adam::new(&mlp, 5e-3);
        let grads = |mlp: &Mlp, x: &Mat| {
            let cache = mlp.forward_cached(x);
            let y = cache.activations.last().unwrap().clone();
            mlp.backward(&cache, &y).0
        };
        let x = Mat::from_vec(4, 2, vec![0.1, -0.2, 0.5, 0.3, -0.7, 0.9, 0.0, 1.0]);
        for _ in 0..25 {
            let g = grads(&mlp, &x);
            opt.step(&mut mlp, &g);
        }
        let restored = Adam::from_json(&Json::parse(&opt.to_json().dump()).unwrap(), &mlp).unwrap();
        assert_eq!(restored.steps(), opt.steps());
        let mut mlp2 = mlp.clone();
        let mut opt2 = restored;
        for _ in 0..10 {
            let g = grads(&mlp, &x);
            opt.step(&mut mlp, &g);
            let g2 = grads(&mlp2, &x);
            opt2.step(&mut mlp2, &g2);
        }
        for (a, b) in mlp.layers.iter().zip(&mlp2.layers) {
            for (x, y) in a.w.data.iter().zip(&b.w.data) {
                assert_eq!(x.to_bits(), y.to_bits(), "restored Adam diverged");
            }
        }
    }

    #[test]
    fn bias_correction_first_step_magnitude() {
        // With bias correction, the very first Adam step is ~lr in magnitude.
        let mut rng = Pcg64::new(2);
        let mut mlp = Mlp::new(&[1, 1], &[Activation::Linear], &mut rng);
        let w0 = mlp.layers[0].w.data[0];
        let mut opt = Adam::new(&mlp, 0.01);
        let grads = MlpGrads {
            w: vec![Mat::from_vec(1, 1, vec![3.7])],
            b: vec![vec![0.0]],
        };
        opt.step(&mut mlp, &grads);
        let delta = (mlp.layers[0].w.data[0] - w0).abs();
        assert!((delta - 0.01).abs() < 1e-4, "delta={delta}");
    }
}
