//! Adam optimizer (Kingma & Ba 2015) over an `Mlp`, with the paper's
//! hyperparameters as defaults: beta1=0.9, beta2=0.999 (actor lr 1e-4,
//! critic lr 1e-3 are passed by the agents).

use super::mlp::{Mlp, MlpGrads};
use crate::tensor::Mat;

/// Adam state (first/second moments) for one `Mlp`.
#[derive(Clone, Debug)]
pub struct Adam {
    /// Learning rate.
    pub lr: f32,
    /// First-moment decay.
    pub beta1: f32,
    /// Second-moment decay.
    pub beta2: f32,
    /// Denominator fuzz.
    pub eps: f32,
    t: u64,
    m_w: Vec<Mat>,
    v_w: Vec<Mat>,
    m_b: Vec<Vec<f32>>,
    v_b: Vec<Vec<f32>>,
}

impl Adam {
    /// Zero-initialized optimizer state shaped like `model`.
    pub fn new(model: &Mlp, lr: f32) -> Self {
        let m_w = model
            .layers
            .iter()
            .map(|l| Mat::zeros(l.w.rows, l.w.cols))
            .collect::<Vec<_>>();
        let v_w = m_w.clone();
        let m_b: Vec<Vec<f32>> = model.layers.iter().map(|l| vec![0.0; l.b.len()]).collect();
        let v_b = m_b.clone();
        Self {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            t: 0,
            m_w,
            v_w,
            m_b,
            v_b,
        }
    }

    /// Number of steps applied so far.
    pub fn steps(&self) -> u64 {
        self.t
    }

    /// Apply one Adam step of `grads` to `model` (grads = dLoss/dparam;
    /// descends).
    pub fn step(&mut self, model: &mut Mlp, grads: &MlpGrads) {
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for li in 0..model.layers.len() {
            let layer = &mut model.layers[li];
            let (mw, vw) = (&mut self.m_w[li], &mut self.v_w[li]);
            for i in 0..layer.w.data.len() {
                let g = grads.w[li].data[i];
                mw.data[i] = self.beta1 * mw.data[i] + (1.0 - self.beta1) * g;
                vw.data[i] = self.beta2 * vw.data[i] + (1.0 - self.beta2) * g * g;
                let mh = mw.data[i] / b1t;
                let vh = vw.data[i] / b2t;
                layer.w.data[i] -= self.lr * mh / (vh.sqrt() + self.eps);
            }
            let (mb, vb) = (&mut self.m_b[li], &mut self.v_b[li]);
            for i in 0..layer.b.len() {
                let g = grads.b[li][i];
                mb[i] = self.beta1 * mb[i] + (1.0 - self.beta1) * g;
                vb[i] = self.beta2 * vb[i] + (1.0 - self.beta2) * g * g;
                let mh = mb[i] / b1t;
                let vh = vb[i] / b2t;
                layer.b[i] -= self.lr * mh / (vh.sqrt() + self.eps);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::mlp::Activation;
    use crate::util::rng::Pcg64;

    #[test]
    fn adam_fits_faster_than_no_update() {
        let mut rng = Pcg64::new(1);
        let mut mlp = Mlp::new(&[1, 8, 1], &[Activation::Tanh, Activation::Linear], &mut rng);
        let mut opt = Adam::new(&mlp, 1e-2);
        let xs: Vec<f32> = (0..32).map(|i| i as f32 / 16.0 - 1.0).collect();
        let mut losses = Vec::new();
        for _ in 0..400 {
            let x = Mat::from_vec(32, 1, xs.clone());
            let cache = mlp.forward_cached(&x);
            let y = cache.activations.last().unwrap();
            let mut dout = Mat::zeros(32, 1);
            let mut loss = 0.0f32;
            for i in 0..32 {
                let t = (2.0 * xs[i]).sin();
                let d = y.at(i, 0) - t;
                loss += d * d / 32.0;
                *dout.at_mut(i, 0) = 2.0 * d / 32.0;
            }
            losses.push(loss);
            let (grads, _) = mlp.backward(&cache, &dout);
            opt.step(&mut mlp, &grads);
        }
        assert!(losses[399] < 0.02, "final loss {}", losses[399]);
        assert!(losses[399] < 0.05 * losses[0]);
        assert_eq!(opt.steps(), 400);
    }

    #[test]
    fn bias_correction_first_step_magnitude() {
        // With bias correction, the very first Adam step is ~lr in magnitude.
        let mut rng = Pcg64::new(2);
        let mut mlp = Mlp::new(&[1, 1], &[Activation::Linear], &mut rng);
        let w0 = mlp.layers[0].w.data[0];
        let mut opt = Adam::new(&mlp, 0.01);
        let grads = MlpGrads {
            w: vec![Mat::from_vec(1, 1, vec![3.7])],
            b: vec![vec![0.0]],
        };
        opt.step(&mut mlp, &grads);
        let delta = (mlp.layers[0].w.data[0] - w0).abs();
        assert!((delta - 0.01).abs() < 1e-4, "delta={delta}");
    }
}
