//! §Exploration-Range claim: on the A72 bit-serial target, MIX beyond
//! 6 bits is slower than INT8 — the hardware fact that motivates capping
//! the MIX exploration range.  Also sweeps the latency model across layer
//! shapes to document the MACs-vs-latency non-proportionality.
//!
//!     cargo bench --bench hw_crossover

mod common;

use galen::compress::{DiscretePolicy, QuantMode};
use galen::hw::{CostModel, HwTarget, LatencySimulator};
use galen::model::ir::test_fixtures::tiny_meta;
use galen::model::{LayerKind, ModelIr};

fn main() {
    galen::util::logging::init(log::LevelFilter::Info);
    // The crossover only shows on MIX-capable widths, so default to the
    // resnet18s structure (no PJRT needed — manifest only); fall back to
    // the fixture so the bench runs without artifacts.
    let variant = std::env::var("GALEN_BENCH_VARIANT").unwrap_or_else(|_| "resnet18s".into());
    let ir = galen::model::load_meta(
        &galen::artifacts_dir().join(format!("meta_{variant}.json")),
    )
    .ok()
    .and_then(|m| ModelIr::from_meta(&m).ok())
    .unwrap_or_else(|| ModelIr::from_meta(&tiny_meta()).unwrap());

    let sim = LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), 1);
    let reference = DiscretePolicy::reference(&ir);

    // ---- whole-model bit-width sweep ----
    let mut rows = Vec::new();
    let header = format!("{:>5} {:>12} {:>10}", "bits", "latency", "vs INT8");
    let int8 = {
        let mut p = reference.clone();
        for l in &mut p.layers {
            l.quant = QuantMode::Int8;
        }
        sim.latency(&ir, &p)
    };
    println!("=== MIX bit-width sweep (whole model, {} layers) ===", ir.layers.len());
    println!("{header}");
    for bits in 1..=8u8 {
        let mut p = reference.clone();
        for l in &mut p.layers {
            l.quant = QuantMode::Mix {
                w_bits: bits,
                a_bits: bits,
            };
        }
        let t = sim.latency(&ir, &p);
        rows.push(format!("{:>5} {:>9.3} ms {:>9.2}x", bits, t * 1e3, int8 / t));
        println!("{}", rows.last().unwrap());
    }
    rows.push(format!("{:>5} {:>9.3} ms {:>9.2}x", "INT8", int8 * 1e3, 1.0));
    println!("{}", rows.last().unwrap());
    common::save_rows("hw_crossover", &header, &rows);

    // find the crossover bit width
    let crossover = (1..=8u8)
        .find(|&bits| {
            let mut p = reference.clone();
            for l in &mut p.layers {
                l.quant = QuantMode::Mix {
                    w_bits: bits,
                    a_bits: bits,
                };
            }
            sim.latency(&ir, &p) > int8
        })
        .unwrap_or(9);
    println!(
        "\ncrossover at {crossover} bits (paper: >6 bits slower than INT8 => cap at 6)"
    );
    assert!(
        (6..=8).contains(&crossover),
        "crossover at {crossover} is outside the paper's 6-8 bit corridor"
    );

    // ---- MACs-vs-latency non-proportionality across conv shapes ----
    println!("\n=== same-MAC conv shapes, different latency (cache boundness) ===");
    let cost = CostModel::new(HwTarget::cortex_a72());
    println!(
        "{:>10} {:>10} {:>10} {:>14} {:>12}",
        "channels", "spatial", "MACs", "fp32 latency", "MACs/s"
    );
    for (c, sp) in [(32usize, 32usize), (64, 16), (128, 8), (256, 4), (512, 2)] {
        let l = galen::model::Layer {
            index: 0,
            name: format!("c{c}s{sp}"),
            kind: LayerKind::Conv,
            cin: c,
            cout: c,
            kernel: 3,
            stride: 1,
            in_spatial: sp,
            out_spatial: sp,
            prunable: true,
            group: -1,
            depthwise: false,
        };
        let t = cost.layer_cost(&l, c, c, QuantMode::Fp32).total();
        println!(
            "{:>10} {:>10} {:>10} {:>11.3} ms {:>12.2e}",
            c,
            sp,
            l.macs(),
            t * 1e3,
            l.macs() as f64 / t
        );
    }
    println!("=> identical MAC counts, up to ~2x latency spread: the paper's\n   direct-metric argument (abstract proxies mispredict).");
}
