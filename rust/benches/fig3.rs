//! Figure 3 regeneration: per-layer policies predicted by the pruning,
//! quantization and joint agents at c = 0.3 (bar labels = remaining
//! channels / bit widths).
//!
//!     cargo bench --bench fig3

mod common;

use galen::agent::AgentKind;
use galen::bench::Bencher;
use galen::coordinator::{policy_report, ExperimentRecord};

fn main() {
    if !common::artifacts_present() {
        return;
    }
    let session = common::session().expect("session");
    let mut b = Bencher::new();
    let target = 0.3;

    for agent in [AgentKind::Pruning, AgentKind::Quantization, AgentKind::Joint] {
        let cfg = common::config(agent, target);
        let outcome = b.once(&format!("fig3/{agent}"), || {
            session.search(&cfg).expect("search")
        });
        println!(
            "\n=== Figure 3{}: {} agent policy (c=0.3, acc {:.2}%, rel.lat {:.1}%) ===",
            match agent {
                AgentKind::Pruning => "a",
                AgentKind::Quantization => "b",
                AgentKind::Joint => "c",
            },
            agent,
            outcome.best.accuracy * 100.0,
            outcome.relative_latency() * 100.0
        );
        println!("{}", policy_report(&session.ir, &outcome.best_policy));
        ExperimentRecord {
            name: format!("fig3_{}_{agent}", common::variant()),
            config: cfg,
            outcome,
        }
        .save(&session.ir, &galen::results_dir())
        .expect("save");
    }
    println!(
        "paper observations to compare: pruning spreads evenly (first layer\n\
         exempt); quantization varies bit widths, INT8 pinned on constraint-\n\
         limited first/last layers, weights quantized stronger than\n\
         activations; joint is less aggressive on both methods."
    );
}
