//! Figure 5 regeneration (appendix): sequential prune->quant and
//! quant->prune schemes versus the concurrent joint search at effective
//! target c = 0.2.
//!
//!     cargo bench --bench fig5

mod common;

use galen::agent::AgentKind;
use galen::bench::Bencher;
use galen::coordinator::policy_report;
use galen::search::quant_histogram;

fn main() {
    if !common::artifacts_present() {
        return;
    }
    let session = common::session().expect("session");
    let mut b = Bencher::new();
    let target = 0.2;
    let proto = common::config(AgentKind::Joint, target);

    let (_s1a, a) = b.once("fig5a/prune-then-quant", || {
        session
            .sequential(AgentKind::Pruning, target, &proto)
            .expect("seq")
    });
    let (_s1b, bb) = b.once("fig5b/quant-then-prune", || {
        session
            .sequential(AgentKind::Quantization, target, &proto)
            .expect("seq")
    });
    let c = b.once("fig5c/joint", || {
        let mut cfg = proto.clone();
        cfg.agent = AgentKind::Joint;
        session.search(&cfg).expect("search")
    });

    for (tag, out) in [("5a prune->quant", &a), ("5b quant->prune", &bb), ("5c joint", &c)] {
        let (mix, int8, fp32) = quant_histogram(&out.best_policy);
        println!(
            "\n=== Figure {tag}: rel.lat {:.1}% acc {:.2}% (MIX {mix} / INT8 {int8} / FP32 {fp32}) ===",
            out.relative_latency() * 100.0,
            out.best.accuracy * 100.0
        );
        println!("{}", policy_report(&session.ir, &out.best_policy));
    }
    println!(
        "paper shape: sequential schemes over-use the second method (quant-\n\
         first ends in aggressive pruning incl. the first layer); the joint\n\
         search balances both with less restrictive compression."
    );
}
