//! Table 2 + Figure 7 regeneration: joint search at c = 0.2 with the
//! sensitivity analysis enabled vs disabled (constant features), comparing
//! the quantitative results and the found policies.
//!
//!     cargo bench --bench table2_fig7

mod common;

use galen::agent::AgentKind;
use galen::bench::Bencher;
use galen::coordinator::{policy_report, ExperimentRecord};
use galen::eval::SensitivityTable;

fn main() {
    if !common::artifacts_present() {
        return;
    }
    let session = common::session().expect("session");
    let mut b = Bencher::new();
    let target = 0.2;
    let cfg = common::config(AgentKind::Joint, target);

    let disabled_table = SensitivityTable::disabled(
        session.ir.layers.len(),
        &session.opts.sensitivity,
        &session.opts.variant,
    );
    let disabled = b.once("table2/joint-no-sensitivity", || {
        session
            .search_from(&cfg, None, Some(&disabled_table))
            .expect("search")
    });
    let enabled = b.once("table2/joint-with-sensitivity", || {
        session.search(&cfg).expect("search")
    });

    // ---- Table 2 ----
    let reference = galen::compress::DiscretePolicy::reference(&session.ir);
    let sim = session.simulator(1);
    let _base_lat = sim.latency(&session.ir, &reference);
    let header = format!(
        "{:14} {:>11} {:>11} {:>9} {:>10}",
        "sensitivity", "MACs", "BOPs", "rel.lat", "accuracy"
    );
    let mut rows = vec![format!(
        "{:14} {:>11.3e} {:>11.3e} {:>8.1}% {:>9.2}%",
        "(uncompressed)",
        reference.macs(&session.ir) as f64,
        reference.bops(&session.ir) as f64,
        100.0,
        session.ir.base_test_acc * 100.0
    )];
    for (name, out) in [("disabled", &disabled), ("enabled", &enabled)] {
        rows.push(format!(
            "{:14} {:>11.3e} {:>11.3e} {:>8.1}% {:>9.2}%",
            name,
            out.best.macs as f64,
            out.best.bops as f64,
            out.relative_latency() * 100.0,
            out.best.accuracy * 100.0
        ));
    }
    println!("\n=== Table 2 (c=0.2, {} variant) ===\n{header}", common::variant());
    for r in &rows {
        println!("{r}");
    }
    common::save_rows(&format!("table2_{}", common::variant()), &header, &rows);

    // ---- Figure 7 ----
    println!("\n=== Figure 7a: joint policy, sensitivity DISABLED ===");
    println!("{}", policy_report(&session.ir, &disabled.best_policy));
    println!("=== Figure 7b: joint policy, sensitivity ENABLED ===");
    println!("{}", policy_report(&session.ir, &enabled.best_policy));
    println!(
        "paper shape: without sensitivity the agent predicts near-uniform\n\
         actions (low per-layer variance) and leans on pruning; with\n\
         sensitivity it differentiates layers and conserves accuracy."
    );

    for (tag, cfg_ref, out) in [
        ("disabled", &cfg, disabled),
        ("enabled", &cfg, enabled),
    ] {
        ExperimentRecord {
            name: format!("table2_{}_{}", common::variant(), tag),
            config: cfg_ref.clone(),
            outcome: out,
        }
        .save(&session.ir, &galen::results_dir())
        .expect("save");
    }
}
