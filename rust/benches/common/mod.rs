#![allow(dead_code)] // each bench uses a subset of these helpers
//! Shared plumbing for the experiment benches (one per paper table/figure).
//!
//! Benches honour environment overrides so the same harness scales from a
//! quick smoke run to the paper protocol:
//!   GALEN_BENCH_VARIANT   model variant (default: micro)
//!   GALEN_BENCH_EPISODES  episodes per search (default: 60)
//!   GALEN_BENCH_PAPER     "1" => paper episode counts (310/410)

use galen::agent::AgentKind;
use galen::coordinator::{Session, SessionOptions};
use galen::search::SearchConfig;

pub fn variant() -> String {
    std::env::var("GALEN_BENCH_VARIANT").unwrap_or_else(|_| "micro".into())
}

pub fn episodes() -> usize {
    std::env::var("GALEN_BENCH_EPISODES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(40) // single-core CI budget; paper protocol via GALEN_BENCH_PAPER=1
}

pub fn session() -> anyhow::Result<Session> {
    galen::util::logging::init(log::LevelFilter::Info);
    let opts = SessionOptions::new(&variant());
    Session::open(opts)
}

pub fn config(agent: AgentKind, target: f64) -> SearchConfig {
    let mut cfg = if std::env::var("GALEN_BENCH_PAPER").as_deref() == Ok("1") {
        SearchConfig::paper(agent, target)
    } else {
        let mut c = SearchConfig::new(agent, target);
        c.episodes = episodes();
        c
    };
    cfg.log_every = 0;
    cfg.eval_batches = 1;
    cfg
}

pub fn artifacts_present() -> bool {
    let ok = galen::artifacts_dir()
        .join(format!("meta_{}.json", variant()))
        .exists();
    if !ok {
        println!(
            "SKIP: artifacts for '{}' not built (run `make artifacts`)",
            variant()
        );
    }
    ok
}

/// Save a bench result table under results/.
pub fn save_rows(name: &str, header: &str, rows: &[String]) {
    let path = galen::results_dir().join(format!("{name}.txt"));
    let _ = std::fs::create_dir_all(galen::results_dir());
    let _ = std::fs::write(&path, format!("{header}\n{}\n", rows.join("\n")));
    println!("[saved {}]", path.display());
}
