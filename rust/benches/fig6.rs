//! Figure 6 regeneration: KL sensitivity over layers, for activation
//! quantization, weight quantization and channel pruning (the paper's
//! 10-point sparsity grid / full bit-width range via --paper grid env).
//!
//!     cargo bench --bench fig6
//!     GALEN_BENCH_PAPER_GRID=1 cargo bench --bench fig6

mod common;

use galen::bench::Bencher;
use galen::coordinator::{Session, SessionOptions};
use galen::eval::SensitivityConfig;

fn main() {
    if !common::artifacts_present() {
        return;
    }
    galen::util::logging::init(log::LevelFilter::Info);
    let mut opts = SessionOptions::new(&common::variant());
    if std::env::var("GALEN_BENCH_PAPER_GRID").as_deref() == Ok("1") {
        opts.sensitivity = SensitivityConfig::paper();
        opts.sensitivity_cache = Some(
            galen::results_dir().join(format!("sensitivity_{}_paper.json", common::variant())),
        );
    }
    let mut b = Bencher::new();
    // session bring-up computes (or loads) the sensitivity table == Fig 6
    let session = b.once("fig6/sensitivity-analysis", || {
        Session::open(opts).expect("session")
    });
    let sens = &session.sens;

    let mut rows = Vec::new();
    let header = format!(
        "{:14} | {:^30} | {:^30} | {:^30}",
        "layer", "a-quant Ω (value:omega)", "w-quant Ω", "prune Ω"
    );
    for l in &session.ir.layers {
        let fmt = |series: &Vec<galen::eval::SensitivityProbe>| {
            series
                .iter()
                .map(|p| format!("{:.0}:{:.3}", p.value * 10.0, p.omega))
                .collect::<Vec<_>>()
                .join(" ")
        };
        rows.push(format!(
            "{:14} | {:30} | {:30} | {:30}",
            l.name,
            fmt(&sens.quant_a[l.index]),
            fmt(&sens.quant_w[l.index]),
            fmt(&sens.prune[l.index]),
        ));
        println!("{}", rows.last().unwrap());
    }
    common::save_rows(&format!("fig6_{}", common::variant()), &header, &rows);

    // the paper's reported trends, quantified:
    let lower_bits_higher_omega = |series: &Vec<Vec<galen::eval::SensitivityProbe>>| {
        let mut ok = 0;
        for l in series {
            if l.first().map(|p| p.omega) >= l.last().map(|p| p.omega) {
                ok += 1;
            }
        }
        (ok, series.len())
    };
    let (wa, wn) = lower_bits_higher_omega(&sens.quant_w);
    let (aa, an) = lower_bits_higher_omega(&sens.quant_a);
    println!(
        "\ntrend check — lowest bit width has the highest Ω on {wa}/{wn} layers (weights), {aa}/{an} (activations)"
    );
}
