//! Table 1 regeneration: compressed-model performance per agent at target
//! compression rates c = 0.3 and c = 0.2 (MACs, BOPs, latency, accuracy).
//!
//!     cargo bench --bench table1
//!     GALEN_BENCH_VARIANT=resnet18s GALEN_BENCH_EPISODES=120 cargo bench --bench table1

mod common;

use galen::agent::AgentKind;
use galen::bench::Bencher;
use galen::coordinator::{table1_header, ExperimentRecord};

fn main() {
    if !common::artifacts_present() {
        return;
    }
    let session = common::session().expect("session");
    let mut b = Bencher::new();
    let mut rows = Vec::new();

    // uncompressed reference row
    let reference = galen::compress::DiscretePolicy::reference(&session.ir);
    let sim = session.simulator(1);
    let base_lat = sim.latency(&session.ir, &reference);
    rows.push(format!(
        "{:16} {:>4} {:>10.3e} {:>10.3e} {:>8.2} ms {:>7.2} % {:>7.1} %",
        "uncompressed",
        "-",
        reference.macs(&session.ir) as f64,
        reference.bops(&session.ir) as f64,
        base_lat * 1e3,
        session.ir.base_test_acc * 100.0,
        100.0
    ));

    for &target in &[0.3, 0.2] {
        for agent in [AgentKind::Pruning, AgentKind::Quantization, AgentKind::Joint] {
            let cfg = common::config(agent, target);
            let outcome = b.once(
                &format!("table1/{agent}/c{target:.1}"),
                || session.search(&cfg).expect("search"),
            );
            let rec = ExperimentRecord {
                name: format!(
                    "table1_{}_{}_c{:03}",
                    common::variant(),
                    agent,
                    (target * 100.0) as u32
                ),
                config: cfg,
                outcome,
            };
            rows.push(rec.table1_row());
            rec.save(&session.ir, &galen::results_dir()).expect("save");
        }
    }

    let header = table1_header();
    println!("\n=== Table 1 ({} variant) ===\n{header}", common::variant());
    for r in &rows {
        println!("{r}");
    }
    common::save_rows(&format!("table1_{}", common::variant()), &header, &rows);
}
