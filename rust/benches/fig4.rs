//! Figure 4 regeneration: accuracy and relative latency of the three agents
//! across target compression rates c in {0.1 ... 0.7}.
//!
//!     cargo bench --bench fig4
//!     GALEN_BENCH_TARGETS=0.2,0.4,0.6 cargo bench --bench fig4   (subset)

mod common;

use galen::agent::AgentKind;
use galen::bench::Bencher;
use galen::coordinator::ExperimentRecord;

fn targets() -> Vec<f64> {
    std::env::var("GALEN_BENCH_TARGETS")
        .ok()
        .map(|s| s.split(',').filter_map(|t| t.parse().ok()).collect())
        .unwrap_or_else(|| vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7])
}

fn main() {
    if !common::artifacts_present() {
        return;
    }
    let session = common::session().expect("session");
    let mut b = Bencher::new();
    let targets = targets();
    let mut rows = Vec::new();
    let header = format!(
        "{:16} {:>5} {:>10} {:>10} {:>9}",
        "agent", "c", "rel.lat", "accuracy", "reward"
    );

    for agent in [AgentKind::Pruning, AgentKind::Quantization, AgentKind::Joint] {
        for &c in &targets {
            let cfg = common::config(agent, c);
            let outcome = b.once(&format!("fig4/{agent}/c{c:.1}"), || {
                session.search(&cfg).expect("search")
            });
            rows.push(format!(
                "{:16} {:>5.2} {:>9.1}% {:>9.2}% {:>9.3}",
                agent,
                c,
                outcome.relative_latency() * 100.0,
                outcome.best.accuracy * 100.0,
                outcome.best.reward
            ));
            println!("{}", rows.last().unwrap());
            ExperimentRecord {
                name: format!(
                    "fig4_{}_{}_c{:03}",
                    common::variant(),
                    agent,
                    (c * 100.0) as u32
                ),
                config: cfg,
                outcome,
            }
            .save(&session.ir, &galen::results_dir())
            .expect("save");
        }
    }

    println!("\n=== Figure 4 ({} variant) ===\n{header}", common::variant());
    for r in &rows {
        println!("{r}");
    }
    common::save_rows(&format!("fig4_{}", common::variant()), &header, &rows);
    println!(
        "\npaper shape to verify: all agents track the target within ~5 pp\n\
         except the quantization agent at extreme c (& accuracy collapse);\n\
         joint >= pruning >= quantization in accuracy at small c."
    );
}
