//! L3 hot-path microbenches (the §Perf baseline/after numbers in
//! EXPERIMENTS.md):
//!   * DDPG optimize step (dominant: the 400/300 MLP GEMMs)
//!   * actor inference (per time step)
//!   * hardware simulator per-policy latency evaluation
//!   * replay buffer sampling
//!   * policy -> runtime-input packing (masks + ℓ1 ranking)
//!   * JSON parse of a meta manifest
//!   * i8 vs f32 GEMM (the measured-latency profiler's kernel substrate),
//!     under the shipped SIMD dispatch and with the scalar oracle forced —
//!     the per-kernel speedups land in the JSON meta block
//!   * depthwise i8 vs f32 conv (the mobilenetv2s kernel substrate), same
//!     auto/scalar twin structure
//!   * parallel sweep orchestrator vs the 1-worker sweep (speedup + the
//!     front-equality determinism verdict, emitted into the JSON meta)
//!   * search driver vs the pre-driver monolith shape: `run_search` (no
//!     observers) vs a driver with a live event observer — the event
//!     stream's overhead budget is < 2% (verdict + pct in the JSON meta)
//!   * observability overhead: the same search with the process-wide
//!     metrics gate ON (the shipped default) vs OFF — the instrumentation
//!     budget is < 2% with metrics on (verdict + pct in the JSON meta)
//!
//!     cargo bench --bench hot_paths

mod common;

use galen::agent::{AgentKind, Ddpg, DdpgConfig, JointMapper, PolicyMapper, Transition};
use galen::bench::Bencher;
use galen::compress::{DiscretePolicy, PolicyInputs};
use galen::hw::{CostModel, HwTarget, LatencyKind, LatencySimulator, ProfilerConfig};
use galen::search::{run_sweep, LatencyFactory, SweepGrid};
use galen::model::ir::test_fixtures::tiny_meta;
use galen::model::{LayerKind, ModelIr};
use galen::tensor::depthwise::{conv_dw_f32, conv_dw_i8, QuantizedDwWeights};
use galen::tensor::quant::{gemm_i8, gemm_i8_packed, QuantizedMat, QuantizedTensor};
use galen::tensor::simd::{self, SimdMode};
use galen::tensor::Mat;
use galen::util::rng::Pcg64;

/// Load the bench IR, preferring the real resnet18s manifest (21 layers)
/// for realistic sizes.  Never falls back silently: the IR actually used is
/// logged, printed, and tagged in the emitted JSON so runs on different IRs
/// are never compared as if they were the same workload.
fn bench_ir() -> (ModelIr, String) {
    let path = galen::artifacts_dir().join("meta_resnet18s.json");
    match galen::model::load_meta(&path).and_then(|m| ModelIr::from_meta(&m)) {
        Ok(ir) => {
            log::info!(
                "hot_paths: using {} ({} layers) from {}",
                ir.variant,
                ir.layers.len(),
                path.display()
            );
            let tag = ir.variant.clone();
            (ir, tag)
        }
        Err(e) => {
            log::warn!(
                "hot_paths: {} unavailable ({e:#}); falling back to the tiny fixture IR — \
                 numbers are NOT comparable to resnet18s runs",
                path.display()
            );
            let ir = ModelIr::from_meta(&tiny_meta()).unwrap();
            let tag = format!("{} (fixture fallback)", ir.variant);
            (ir, tag)
        }
    }
}

fn main() {
    galen::util::logging::init(log::LevelFilter::Warn);
    let mut b = Bencher::new();
    let (ir, ir_tag) = bench_ir();
    println!("IR: {ir_tag} ({} layers)\n", ir.layers.len());
    Bencher::header();
    let mut rng = Pcg64::new(1);

    // ---- DDPG: paper-sized nets (state ~30, actions 3, hidden 400/300) ----
    let state_dim = 30;
    let mut agent = Ddpg::new(state_dim, 3, DdpgConfig::default(), 7);
    for _ in 0..2000 {
        let s: Vec<f32> = (0..state_dim).map(|_| rng.next_f32()).collect();
        let ns: Vec<f32> = (0..state_dim).map(|_| rng.next_f32()).collect();
        let a: Vec<f32> = (0..3).map(|_| rng.next_f32()).collect();
        agent.store(Transition {
            state: s,
            action: a,
            reward: rng.next_f32(),
            next_state: ns,
            terminal: rng.below(20) == 0,
        });
    }
    let probe: Vec<f32> = (0..state_dim).map(|_| rng.next_f32()).collect();
    b.iter("ddpg/actor-inference (1 step)", || {
        agent.act(&probe, true, false)
    });
    b.iter("ddpg/optimize (batch 128)", || agent.optimize());

    // ---- replay sampling ----
    let replay = agent.replay.clone();
    let mut rrng = Pcg64::new(3);
    b.iter("replay/sample-128", || replay.sample(128, &mut rrng));

    // ---- hardware simulator ----
    let sim = LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), 5);
    let mapper = JointMapper::default();
    let mut policies = Vec::new();
    for _ in 0..64 {
        let mut p = DiscretePolicy::reference(&ir);
        for i in 0..ir.layers.len() {
            mapper.apply(
                &ir,
                &mut p,
                i,
                &[rrng.next_f32(), rrng.next_f32(), rrng.next_f32()],
            );
        }
        policies.push(p);
    }
    let mut pi = 0usize;
    b.iter("hw/latency (full model policy)", || {
        pi = (pi + 1) % policies.len();
        sim.latency(&ir, &policies[pi])
    });

    // ---- policy -> runtime inputs (ℓ1 ranking + mask building) ----
    let weights: std::collections::BTreeMap<String, (Vec<usize>, Vec<f32>)> = ir
        .layers
        .iter()
        .map(|l| {
            let shape = match l.kind {
                LayerKind::Conv => vec![l.kernel, l.kernel, l.cin, l.cout],
                LayerKind::Linear => vec![l.cin, l.cout],
            };
            let n: usize = shape.iter().product();
            let mut v = vec![0.0f32; n];
            for x in &mut v {
                *x = rrng.next_f32() - 0.5;
            }
            (format!("{}.w", l.name), (shape, v))
        })
        .collect();
    let rankings = galen::compress::precompute_rankings(&ir, &weights);
    b.iter("compress/policy-input packing (cached ℓ1)", || {
        pi = (pi + 1) % policies.len();
        PolicyInputs::build_with_rankings(&ir, &policies[pi], &rankings).unwrap()
    });

    // ---- full search episode against the synthetic evaluator ----
    let sens = galen::eval::SensitivityTable::disabled(
        ir.layers.len(),
        &galen::eval::SensitivityConfig::default(),
        &ir.variant,
    );
    b.iter("search/episode (synthetic eval)", || {
        let ev = galen::search::SimEvaluator::new(&ir);
        let mut cfg = galen::search::SearchConfig::fast(AgentKind::Joint, 0.3);
        cfg.episodes = 1;
        cfg.warmup_episodes = 1;
        cfg.log_every = 0;
        let mut s = LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), 5);
        galen::search::run_search(&ir, &sens, &ev, &mut s, &mapper, &cfg, None).unwrap()
    });

    // ---- search driver vs the pre-driver monolith shape ----
    // Identical 3-episode searches: the bare run_search wrapper (the old
    // monolith's call shape, zero observers) vs a manually built driver
    // streaming every SearchEvent into an observer.  The delta is the cost
    // of the event stream itself; the budget is < 2%.
    let mut drv_cfg = galen::search::SearchConfig::fast(AgentKind::Joint, 0.3);
    drv_cfg.episodes = 3;
    drv_cfg.warmup_episodes = 1;
    drv_cfg.log_every = 0;
    let plain_ns = b
        .iter("search/driver_vs_monolith/run_search (3 ep)", || {
            let ev = galen::search::SimEvaluator::new(&ir);
            let mut s = LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), 5);
            galen::search::run_search(&ir, &sens, &ev, &mut s, &mapper, &drv_cfg, None).unwrap()
        })
        .median_ns();
    let events_ns = b
        .iter("search/driver_vs_monolith/driver+events (3 ep)", || {
            let ev = galen::search::SimEvaluator::new(&ir);
            let mut s = LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), 5);
            let mut driver = galen::search::SearchBuilder::from_config(drv_cfg.clone())
                .build(&ir, &sens, &ev, &mut s, &mapper)
                .unwrap();
            driver.add_observer(|e: &galen::search::SearchEvent| {
                std::hint::black_box(e);
            });
            driver.run_to_completion().unwrap()
        })
        .median_ns();
    let driver_event_overhead_pct = (events_ns / plain_ns - 1.0) * 100.0;
    println!(
        "search driver event-stream overhead: {driver_event_overhead_pct:+.2}% \
         (budget < 2%)"
    );

    // ---- observability overhead: metrics on vs everything off ----
    // The same 3-episode search with the process-wide metrics gate OFF vs
    // ON (the shipped default); tracing is off in both runs (GALEN_TRACE
    // is never set here).  The delta is the full cost of the registry
    // instrumentation on the hottest path we ship — step counters, reward
    // gauges, cache counters, measurement histograms.  Budget: < 2% with
    // metrics on; the off run demonstrates the disabled gate costs one
    // relaxed load + branch per site.  The gate is restored to its default
    // (on) before any later section runs.
    galen::obs::metrics::set_enabled(false);
    let metrics_off_ns = b
        .iter("search/obs_overhead/metrics-off (3 ep)", || {
            let ev = galen::search::SimEvaluator::new(&ir);
            let mut s = LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), 5);
            galen::search::run_search(&ir, &sens, &ev, &mut s, &mapper, &drv_cfg, None).unwrap()
        })
        .median_ns();
    galen::obs::metrics::set_enabled(true);
    let metrics_on_ns = b
        .iter("search/obs_overhead/metrics-on (3 ep)", || {
            let ev = galen::search::SimEvaluator::new(&ir);
            let mut s = LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), 5);
            galen::search::run_search(&ir, &sens, &ev, &mut s, &mapper, &drv_cfg, None).unwrap()
        })
        .median_ns();
    let obs_overhead_pct = (metrics_on_ns / metrics_off_ns - 1.0) * 100.0;
    println!("observability metrics overhead: {obs_overhead_pct:+.2}% (budget < 2%)");

    // ---- parallel sweep orchestrator: N workers vs 1 on the same grid ----
    // 6 jobs (3 agents x 2 targets) of deliberately tiny searches: the
    // section tracks orchestrator throughput (fan-out overhead, shared
    // latency caches), not search quality.  Fresh factories per run keep
    // the two runs cache-independent; the speedup and the front-equality
    // verdict land in BENCH_hot_paths.json's meta block.
    let mut sweep_proto = galen::search::SearchConfig::fast(AgentKind::Joint, 0.5);
    sweep_proto.episodes = 8;
    sweep_proto.warmup_episodes = 3;
    sweep_proto.log_every = 0;
    let sweep_grid = SweepGrid::new(
        vec![AgentKind::Pruning, AgentKind::Quantization, AgentKind::Joint],
        vec![0.35, 0.6],
    );
    let mk_factory = || {
        LatencyFactory::new(
            LatencyKind::Sim,
            HwTarget::cortex_a72(),
            &ir.variant,
            ProfilerConfig::fast(),
            None,
        )
    };
    let sweep_workers = galen::util::num_threads().clamp(2, 4);
    let seq_report = b.once("sweep/parallel_vs_sequential/1-worker (6 jobs)", || {
        run_sweep(&ir, &sens, &sweep_grid, &sweep_proto, 1, &mk_factory()).unwrap()
    });
    let par_report = b.once(
        &format!("sweep/parallel_vs_sequential/{sweep_workers}-worker (6 jobs)"),
        || run_sweep(&ir, &sens, &sweep_grid, &sweep_proto, sweep_workers, &mk_factory()).unwrap(),
    );
    let sweep_speedup = seq_report.wall_s / par_report.wall_s;
    let sweep_fronts_identical = seq_report.front == par_report.front;
    println!(
        "sweep orchestrator: {sweep_workers}-worker speedup {sweep_speedup:.2}x, \
         fronts identical: {sweep_fronts_identical}"
    );

    // ---- i8 vs f32 GEMM (measured-latency profiler kernel substrate) ----
    // 64x576x64 is the im2col shape of a 64->64 3x3 conv at 8x8 spatial —
    // a mid-sized resnet18s layer.  All three kernels run serially so the
    // numbers track kernel quality, not thread-pool behavior.  The i8
    // entries include the per-call dynamic activation quantize, exactly as
    // the profiler times them.  Each kernel runs twice: under the shipped
    // `GALEN_SIMD=auto` dispatch (the unsuffixed labels the bench gate
    // tracks) and with the scalar oracle forced, so the emitted meta block
    // carries the measured SIMD speedups.  Results are bit-identical
    // either way — only the timings differ.
    let prev_mode = simd::mode();
    simd::set_mode(SimdMode::Auto);
    let tile = simd::autotune();
    simd::set_tile_config(tile);
    let simd_isa = simd::isa_label().to_string();
    println!("kernel dispatch: {simd_isa} (tile kc={} mc={} par_min_macs={})",
        tile.kc, tile.mc, tile.par_min_macs);
    let (gm, gk, gn) = (64, 576, 64);
    let mut ga = Mat::zeros(gm, gk);
    let mut gw = Mat::zeros(gk, gn);
    for x in ga.data.iter_mut().chain(&mut gw.data) {
        *x = rrng.next_f32() * 2.0 - 1.0;
    }
    let mut gout = Mat::zeros(gm, gn);
    let f32_auto_ns = b
        .iter("tensor/i8_vs_f32_gemm/f32 64x576x64", || {
            ga.matmul_into_threaded(&gw, &mut gout, 1)
        })
        .median_ns();
    let qw = QuantizedMat::quantize_per_channel(&gw);
    let packed = qw.pack();
    let mut qa = QuantizedTensor::quantize(&ga);
    let mut acc: Vec<i32> = Vec::new();
    let i8_auto_ns = b
        .iter("tensor/i8_vs_f32_gemm/i8 64x576x64", || {
            qa.requantize(&ga);
            gemm_i8(&qa, &qw, &mut acc, &mut gout);
        })
        .median_ns();
    let i8_packed_auto_ns = b
        .iter("tensor/i8_vs_f32_gemm/i8_packed 64x576x64", || {
            qa.requantize(&ga);
            gemm_i8_packed(&qa, &packed, &mut acc, &mut gout);
        })
        .median_ns();
    simd::set_mode(SimdMode::Scalar);
    let f32_scalar_ns = b
        .iter("tensor/i8_vs_f32_gemm/f32 64x576x64 (scalar oracle)", || {
            ga.matmul_into_threaded(&gw, &mut gout, 1)
        })
        .median_ns();
    let i8_scalar_ns = b
        .iter("tensor/i8_vs_f32_gemm/i8 64x576x64 (scalar oracle)", || {
            qa.requantize(&ga);
            gemm_i8(&qa, &qw, &mut acc, &mut gout);
        })
        .median_ns();
    let i8_packed_scalar_ns = b
        .iter(
            "tensor/i8_vs_f32_gemm/i8_packed 64x576x64 (scalar oracle)",
            || {
                qa.requantize(&ga);
                gemm_i8_packed(&qa, &packed, &mut acc, &mut gout);
            },
        )
        .median_ns();
    simd::set_mode(SimdMode::Auto);

    // ---- depthwise i8 vs f32 (mobilenetv2s kernel substrate) ----
    // 96 channels at 16x16, 3x3 stride 1 — the s1b1.dw shape of the zoo's
    // mobilenetv2s.  Both kernels are serial by construction; the i8 entry
    // includes the per-call dynamic activation quantize, exactly as the
    // measured-latency profiler times depthwise configs.  Scalar-forced
    // twins follow the auto entries, as in the GEMM section.
    let (dc, dsp) = (96usize, 16usize);
    let mut din = Mat::zeros(dc, dsp * dsp);
    let mut dw_w = vec![0.0f32; dc * 9];
    for x in din.data.iter_mut().chain(&mut dw_w) {
        *x = rrng.next_f32() * 2.0 - 1.0;
    }
    let mut dout = vec![0.0f32; dc * dsp * dsp];
    let dw_f32_auto_ns = b
        .iter("tensor/depthwise_i8_vs_f32/f32 96x16x16 k3", || {
            conv_dw_f32(&din.data, dc, dsp, dsp, 3, 1, &dw_w, &mut dout)
        })
        .median_ns();
    let qdw = QuantizedDwWeights::quantize(&dw_w, dc, 3);
    let mut qdin = QuantizedTensor::quantize(&din);
    let dw_i8_auto_ns = b
        .iter("tensor/depthwise_i8_vs_f32/i8 96x16x16 k3", || {
            qdin.requantize(&din);
            conv_dw_i8(&qdin.data, qdin.scale, dc, dsp, dsp, 1, &qdw, &mut dout);
        })
        .median_ns();
    simd::set_mode(SimdMode::Scalar);
    let dw_f32_scalar_ns = b
        .iter("tensor/depthwise_i8_vs_f32/f32 96x16x16 k3 (scalar oracle)", || {
            conv_dw_f32(&din.data, dc, dsp, dsp, 3, 1, &dw_w, &mut dout)
        })
        .median_ns();
    let dw_i8_scalar_ns = b
        .iter("tensor/depthwise_i8_vs_f32/i8 96x16x16 k3 (scalar oracle)", || {
            qdin.requantize(&din);
            conv_dw_i8(&qdin.data, qdin.scale, dc, dsp, dsp, 1, &qdw, &mut dout);
        })
        .median_ns();
    simd::set_mode(prev_mode);
    let simd_f32_gemm_speedup = f32_scalar_ns / f32_auto_ns;
    let simd_i8_gemm_speedup = i8_scalar_ns / i8_auto_ns;
    let simd_i8_packed_speedup = i8_packed_scalar_ns / i8_packed_auto_ns;
    let simd_dw_f32_speedup = dw_f32_scalar_ns / dw_f32_auto_ns;
    let simd_dw_i8_speedup = dw_i8_scalar_ns / dw_i8_auto_ns;
    println!(
        "SIMD speedups vs scalar oracle ({simd_isa}): f32 gemm {simd_f32_gemm_speedup:.2}x, \
         i8 gemm {simd_i8_gemm_speedup:.2}x, i8 packed {simd_i8_packed_speedup:.2}x, \
         dw f32 {simd_dw_f32_speedup:.2}x, dw i8 {simd_dw_i8_speedup:.2}x"
    );

    // ---- JSON manifest parse ----
    let meta_path = galen::artifacts_dir().join("meta_resnet18s.json");
    if let Ok(text) = std::fs::read_to_string(&meta_path) {
        b.iter("json/parse meta_resnet18s", || {
            galen::util::json::Json::parse(&text).unwrap()
        });
    }

    // machine-readable trajectory file at the repo root (EXPERIMENTS.md §Perf)
    let json_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate lives one level below the repo root")
        .join("BENCH_hot_paths.json");
    let threads = galen::util::num_threads().to_string();
    b.write_json(
        &json_path,
        &[
            ("ir", ir_tag),
            ("gemm_threads", threads),
            ("sweep_workers", sweep_workers.to_string()),
            ("sweep_parallel_speedup", format!("{sweep_speedup:.3}")),
            ("sweep_fronts_identical", sweep_fronts_identical.to_string()),
            (
                "driver_event_overhead_pct",
                format!("{driver_event_overhead_pct:.3}"),
            ),
            (
                "driver_event_overhead_ok",
                (driver_event_overhead_pct < 2.0).to_string(),
            ),
            ("obs_overhead_pct", format!("{obs_overhead_pct:.3}")),
            ("obs_overhead_ok", (obs_overhead_pct < 2.0).to_string()),
            ("simd_isa", simd_isa),
            ("tile_kc", tile.kc.to_string()),
            ("tile_mc", tile.mc.to_string()),
            ("tile_par_min_macs", tile.par_min_macs.to_string()),
            ("simd_f32_gemm_speedup", format!("{simd_f32_gemm_speedup:.3}")),
            ("simd_i8_gemm_speedup", format!("{simd_i8_gemm_speedup:.3}")),
            ("simd_i8_packed_speedup", format!("{simd_i8_packed_speedup:.3}")),
            ("simd_dw_f32_speedup", format!("{simd_dw_f32_speedup:.3}")),
            ("simd_dw_i8_speedup", format!("{simd_dw_i8_speedup:.3}")),
        ],
    )
    .expect("write BENCH_hot_paths.json");
    println!("\nwrote {}", json_path.display());
    println!("(benchmarks feed EXPERIMENTS.md §Perf)");
}
