//! Property-based invariant tests (galen::testing::forall) over the
//! policy-mapping chain, the hardware cost model and the DDPG plumbing —
//! artifact-free, so they always run.

use galen::agent::{JointMapper, PolicyMapper, PruningMapper, QuantizationMapper};
use galen::compress::{discretize, select_quant_mode, DiscretePolicy, DiscretizeOpts, QuantMode};
use galen::hw::{CostModel, HwTarget, LatencySimulator};
use galen::model::ir::test_fixtures::tiny_meta;
use galen::model::ModelIr;
use galen::tensor::quant::{
    gemm_i8_i32, gemm_i8_packed_i32, PackedRhsI8, QuantizedMat, QuantizedTensor,
};
use galen::tensor::Mat;
use galen::testing::{forall, Config};
use galen::util::rng::Pcg64;

fn ir() -> ModelIr {
    ModelIr::from_meta(&tiny_meta()).unwrap()
}

// ---------------------------------------------------------------- GEMM ----

/// Naive triple-loop references: single accumulator, ascending reduction
/// index — the semantics the optimized kernels must reproduce.
fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
    let mut out = Mat::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for j in 0..b.cols {
            let mut s = 0.0f32;
            for k in 0..a.cols {
                s += a.at(i, k) * b.at(k, j);
            }
            *out.at_mut(i, j) = s;
        }
    }
    out
}

fn naive_t_matmul(a: &Mat, b: &Mat) -> Mat {
    let mut out = Mat::zeros(a.cols, b.cols);
    for i in 0..a.cols {
        for j in 0..b.cols {
            let mut s = 0.0f32;
            for r in 0..a.rows {
                s += a.at(r, i) * b.at(r, j);
            }
            *out.at_mut(i, j) = s;
        }
    }
    out
}

fn naive_matmul_t(a: &Mat, b: &Mat) -> Mat {
    let mut out = Mat::zeros(a.rows, b.rows);
    for i in 0..a.rows {
        for j in 0..b.rows {
            let mut s = 0.0f32;
            for k in 0..a.cols {
                s += a.at(i, k) * b.at(j, k);
            }
            *out.at_mut(i, j) = s;
        }
    }
    out
}

/// Matrix of "exact" values: multiples of 0.25 in [-8, 8].  Every product
/// (granularity 2^-4, magnitude <= 64) and every partial sum over the
/// shapes below stays exactly representable in f32, so *any* summation
/// order must produce bit-identical results — which turns FP equality into
/// a legitimate bit-exactness oracle for the blocked kernels.
fn exact_mat(rng: &mut Pcg64, rows: usize, cols: usize) -> Mat {
    let mut m = Mat::zeros(rows, cols);
    for v in &mut m.data {
        *v = (rng.below(65) as f32 - 32.0) * 0.25;
    }
    m
}

#[test]
fn prop_gemm_blocked_bit_exact_vs_naive_reference() {
    forall(
        Config { cases: 120, ..Default::default() },
        |rng: &mut Pcg64| {
            let m = 1 + rng.below(24);
            // crosses the 4-wide unroll remainders AND the KC=256 k-panel
            let k = 1 + rng.below(280);
            let n = 1 + rng.below(24);
            let a = exact_mat(rng, m, k);
            let b = exact_mat(rng, k, n);
            let bt = exact_mat(rng, n, k);
            let c = exact_mat(rng, m, n);
            (a, b, bt, c)
        },
        |(a, b, bt, c)| {
            if a.matmul(b) != naive_matmul(a, b) {
                return Err("matmul differs from naive reference".into());
            }
            if a.t_matmul(c) != naive_t_matmul(a, c) {
                return Err("t_matmul differs from naive reference".into());
            }
            if a.matmul_t(bt) != naive_matmul_t(a, bt) {
                return Err("matmul_t differs from naive reference".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_gemm_thread_count_invariant() {
    // Full-random values (rounding now matters): every worker count must be
    // bit-identical to the serial kernel, because each thread owns disjoint
    // output rows and runs the identical per-row code.
    forall(
        Config { cases: 60, ..Default::default() },
        |rng: &mut Pcg64| {
            let m = 1 + rng.below(40);
            let k = 1 + rng.below(96);
            let n = 1 + rng.below(40);
            let workers = 2 + rng.below(7);
            let mut a = Mat::zeros(m, k);
            let mut b = Mat::zeros(k, n);
            let mut bt = Mat::zeros(n, k);
            let mut c = Mat::zeros(m, n);
            for v in a
                .data
                .iter_mut()
                .chain(&mut b.data)
                .chain(&mut bt.data)
                .chain(&mut c.data)
            {
                *v = rng.normal() as f32;
            }
            (a, b, bt, c, workers)
        },
        |(a, b, bt, c, workers)| {
            let mut serial = Mat::zeros(0, 0);
            let mut parallel = Mat::zeros(0, 0);
            a.matmul_into_threaded(b, &mut serial, 1);
            a.matmul_into_threaded(b, &mut parallel, *workers);
            if serial != parallel {
                return Err(format!("matmul not deterministic at {workers} workers"));
            }
            a.t_matmul_into_threaded(c, &mut serial, 1);
            a.t_matmul_into_threaded(c, &mut parallel, *workers);
            if serial != parallel {
                return Err(format!("t_matmul not deterministic at {workers} workers"));
            }
            a.matmul_t_into_threaded(bt, &mut serial, 1);
            a.matmul_t_into_threaded(bt, &mut parallel, *workers);
            if serial != parallel {
                return Err(format!("matmul_t not deterministic at {workers} workers"));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------- quantized GEMM ----

#[test]
fn prop_i8_quantize_dequantize_roundtrip_bounded() {
    // Pins the round-trip error contract of symmetric i8 quantization: for
    // every element, |x - deq(q(x))| <= scale / 2 (round-to-nearest, no
    // clamping distortion because scale = max|x| / 127).  Holds per tensor
    // for activations and per column for per-channel weights.
    forall(
        Config { cases: 150, ..Default::default() },
        |rng: &mut Pcg64| {
            let rows = 1 + rng.below(16);
            let cols = 1 + rng.below(16);
            let amp = 10f32.powf(rng.uniform(-3.0, 3.0) as f32);
            let mut m = Mat::zeros(rows, cols);
            for x in &mut m.data {
                *x = (rng.next_f32() * 2.0 - 1.0) * amp;
            }
            m
        },
        |m| {
            let qt = QuantizedTensor::quantize(m);
            let back = qt.dequantize();
            let tol = qt.scale * 0.5 * (1.0 + 1e-5);
            for (x, y) in m.data.iter().zip(&back.data) {
                if (x - y).abs() > tol {
                    return Err(format!("per-tensor: |{x} - {y}| > {tol}"));
                }
            }
            let qm = QuantizedMat::quantize_per_channel(m);
            let back = qm.dequantize();
            for i in 0..m.rows {
                for j in 0..m.cols {
                    let tol = qm.scales[j] * 0.5 * (1.0 + 1e-5);
                    let (x, y) = (m.at(i, j), back.at(i, j));
                    if (x - y).abs() > tol {
                        return Err(format!("per-channel [{i},{j}]: |{x} - {y}| > {tol}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_i8_gemm_parity_with_f32_reference_on_exact_values() {
    // Integer i8 x i8 -> i32 accumulation is exact, and an f32 GEMM over
    // the same small-integer values is exact too (products <= 16129, sums
    // well below 2^24) — so the two kernels must agree *bit for bit*, for
    // shapes crossing the 4-wide unroll tails and the KC k-panel, packed
    // and unpacked alike.
    forall(
        Config { cases: 80, ..Default::default() },
        |rng: &mut Pcg64| {
            let m = 1 + rng.below(12);
            let k = 1 + rng.below(280); // crosses KC=256
            let n = 1 + rng.below(12);
            let a: Vec<i8> = (0..m * k).map(|_| rng.below(33) as i8 - 16).collect();
            let b: Vec<i8> = (0..k * n).map(|_| rng.below(33) as i8 - 16).collect();
            (m, k, n, a, b)
        },
        |(m, k, n, a, b)| {
            let (m, k, n) = (*m, *k, *n);
            // f32 reference over the identical integer values
            let af = Mat::from_vec(m, k, a.iter().map(|&x| x as f32).collect());
            let bf = Mat::from_vec(k, n, b.iter().map(|&x| x as f32).collect());
            let reference = af.matmul(&bf);

            let mut flat = vec![0i32; m * n];
            gemm_i8_i32(a, k, b, n, &mut flat);
            for (q, &r) in flat.iter().zip(&reference.data) {
                if *q != r as i32 {
                    return Err(format!("i8 gemm {q} != f32 reference {r}"));
                }
            }

            let packed = PackedRhsI8::pack(b, k, n, vec![1.0; n]);
            let mut pk = vec![0i32; m * n];
            gemm_i8_packed_i32(a, k, &packed, &mut pk);
            if pk != flat {
                return Err("packed kernel diverges from unpacked".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_discretize_in_range_and_monotone() {
    forall(
        Config::default(),
        |rng: &mut Pcg64| {
            let v = 1 + rng.below(512);
            let r1 = rng.next_f64();
            let r2 = rng.next_f64();
            let m = [1usize, 8, 32][rng.below(3)];
            (v, r1.min(r2), r1.max(r2), m)
        },
        |&(v, rlo, rhi, m)| {
            let opts = DiscretizeOpts {
                channel_multiple: m,
                min_channels: 1,
            };
            let clo = discretize(rlo, v, opts);
            let chi = discretize(rhi, v, opts);
            if !(1..=v).contains(&clo) || !(1..=v).contains(&chi) {
                return Err(format!("out of range: {clo} {chi} of {v}"));
            }
            if chi > clo {
                return Err(format!("not monotone: r{rlo}->{clo} r{rhi}->{chi}"));
            }
            if m > 1 && clo % m != 0 && clo != v {
                return Err(format!("rounding violated: {clo} % {m}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_quant_mode_selection_total_and_bounded() {
    forall(
        Config::default(),
        |rng: &mut Pcg64| {
            (
                rng.next_f64(),
                rng.next_f64(),
                rng.below(2) == 0,
                1 + rng.below(8) as u8,
            )
        },
        |&(a, w, supported, max_bits)| {
            let mode = select_quant_mode(a, w, supported, max_bits);
            match mode {
                QuantMode::Mix { w_bits, a_bits } => {
                    if !supported {
                        return Err("MIX chosen while unsupported".into());
                    }
                    if w_bits == 0 || w_bits > max_bits || a_bits == 0 || a_bits > max_bits {
                        return Err(format!("bits out of range: w{w_bits} a{a_bits}"));
                    }
                }
                QuantMode::Int8 | QuantMode::Fp32 => {}
            }
            Ok(())
        },
    );
}

#[test]
fn prop_policy_macs_bops_consistency() {
    // For ANY policy produced by the joint mapper: macs <= total, bops <=
    // macs*32*32, bops >= macs (>=1 bit per operand).
    let ir = ir();
    let mapper = JointMapper::default();
    forall(
        Config { cases: 200, ..Default::default() },
        |rng: &mut Pcg64| {
            let mut actions = Vec::new();
            for _ in 0..ir.layers.len() {
                actions.push([rng.next_f32(), rng.next_f32(), rng.next_f32()]);
            }
            actions
        },
        |actions| {
            let mut p = DiscretePolicy::reference(&ir);
            for (i, a) in actions.iter().enumerate() {
                mapper.apply(&ir, &mut p, i, a);
            }
            let macs = p.macs(&ir);
            let bops = p.bops(&ir);
            if macs > ir.total_macs() {
                return Err(format!("macs {macs} > total {}", ir.total_macs()));
            }
            if bops > macs * 32 * 32 {
                return Err("bops exceed fp32 bound".into());
            }
            if bops < macs {
                return Err("bops below 1-bit floor".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_latency_positive_and_compression_never_hurts_much() {
    // Latency under any mapped policy stays positive and within 2x of the
    // reference (compression should never inflate cost beyond noise terms).
    let ir = ir();
    let sim = LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), 1);
    let base = sim.latency(&ir, &DiscretePolicy::reference(&ir));
    let mapper = JointMapper::default();
    forall(
        Config { cases: 200, ..Default::default() },
        |rng: &mut Pcg64| {
            (0..ir.layers.len())
                .map(|_| [rng.next_f32(), rng.next_f32(), rng.next_f32()])
                .collect::<Vec<_>>()
        },
        |actions| {
            let mut p = DiscretePolicy::reference(&ir);
            for (i, a) in actions.iter().enumerate() {
                mapper.apply(&ir, &mut p, i, a);
            }
            let lat = sim.latency(&ir, &p);
            if !(lat > 0.0) {
                return Err(format!("non-positive latency {lat}"));
            }
            if lat > base * 2.0 {
                return Err(format!("latency blew up: {lat} vs base {base}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pruning_mapper_group_safety() {
    // No action sequence may change the channel count of a dependency-
    // coupled (group) layer.
    let ir = ir();
    for mapper in [PruningMapper::default(), PruningMapper::rounded()] {
        forall(
            Config { cases: 150, ..Default::default() },
            |rng: &mut Pcg64| {
                (0..ir.layers.len())
                    .map(|_| [rng.next_f32()])
                    .collect::<Vec<_>>()
            },
            |actions| {
                let mut p = DiscretePolicy::reference(&ir);
                for (i, a) in actions.iter().enumerate() {
                    mapper.apply(&ir, &mut p, i, a);
                }
                for l in &ir.layers {
                    if !l.prunable && p.layers[l.index].kept_channels != l.cout {
                        return Err(format!("group layer {} pruned", l.name));
                    }
                }
                Ok(())
            },
        );
    }
}

#[test]
fn prop_quant_mapper_respects_hardware_support() {
    let ir = ir();
    let mapper = QuantizationMapper::default();
    let cost = CostModel::new(HwTarget::cortex_a72());
    forall(
        Config { cases: 150, ..Default::default() },
        |rng: &mut Pcg64| {
            (0..ir.layers.len())
                .map(|_| [rng.next_f32(), rng.next_f32()])
                .collect::<Vec<_>>()
        },
        |actions| {
            let mut p = DiscretePolicy::reference(&ir);
            for (i, a) in actions.iter().enumerate() {
                mapper.apply(&ir, &mut p, i, a);
            }
            // the mapper must never emit a mode the runtime would reject:
            // effective_mode must be the identity on the mapped policy
            for l in &ir.layers {
                let cin = p.effective_cin(&ir, l.index);
                let eff = cost.effective_mode(l, cin, p.layers[l.index].kept_channels, p.layers[l.index].quant);
                if eff != p.layers[l.index].quant {
                    return Err(format!(
                        "layer {}: mapper emitted {:?}, runtime runs {:?}",
                        l.name, p.layers[l.index].quant, eff
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sim_memoized_latency_matches_uncached() {
    // One long-lived (warm-cache) simulator vs the memoization-free sum of
    // per-layer costs, across random mapped policies: identical results.
    let ir = ir();
    let sim = LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), 2);
    let mapper = JointMapper::default();
    forall(
        Config { cases: 200, ..Default::default() },
        |rng: &mut Pcg64| {
            (0..ir.layers.len())
                .map(|_| [rng.next_f32(), rng.next_f32(), rng.next_f32()])
                .collect::<Vec<_>>()
        },
        |actions| {
            let mut p = DiscretePolicy::reference(&ir);
            for (i, a) in actions.iter().enumerate() {
                mapper.apply(&ir, &mut p, i, a);
            }
            let cached = sim.latency(&ir, &p);
            let uncached: f64 = ir
                .layers
                .iter()
                .map(|l| {
                    let cmp = &p.layers[l.index];
                    let eff_cin = p.effective_cin(&ir, l.index);
                    sim.cost
                        .layer_total(l, eff_cin, cmp.kept_channels, cmp.quant)
                })
                .sum();
            if cached != uncached {
                return Err(format!("memoized {cached} != uncached {uncached}"));
            }
            Ok(())
        },
    );
    let (hits, misses) = sim.cache_stats();
    assert!(hits > 0, "cache never hit across 200 policies");
    assert!(misses > 0);
}

#[test]
fn prop_rng_truncated_normal_always_in_bounds() {
    forall(
        Config { cases: 300, ..Default::default() },
        |rng: &mut Pcg64| {
            (
                rng.uniform(-2.0, 3.0),
                rng.uniform(0.0, 2.0),
                rng.next_u64(),
            )
        },
        |&(mu, sigma, seed)| {
            let mut r = Pcg64::new(seed);
            for _ in 0..16 {
                let x = r.truncated_normal(mu, sigma, 0.0, 1.0);
                if !(0.0..=1.0).contains(&x) {
                    return Err(format!("sample {x} outside [0,1]"));
                }
            }
            Ok(())
        },
    );
}
