//! Property-based invariant tests (galen::testing::forall) over the
//! policy-mapping chain, the hardware cost model and the DDPG plumbing —
//! artifact-free, so they always run.

use galen::agent::{JointMapper, PolicyMapper, PruningMapper, QuantizationMapper};
use galen::compress::{discretize, select_quant_mode, DiscretePolicy, DiscretizeOpts, QuantMode};
use galen::hw::{CostModel, HwTarget, LatencySimulator};
use galen::model::ir::test_fixtures::tiny_meta;
use galen::model::ModelIr;
use galen::testing::{forall, Config};
use galen::util::rng::Pcg64;

fn ir() -> ModelIr {
    ModelIr::from_meta(&tiny_meta()).unwrap()
}

#[test]
fn prop_discretize_in_range_and_monotone() {
    forall(
        Config::default(),
        |rng: &mut Pcg64| {
            let v = 1 + rng.below(512);
            let r1 = rng.next_f64();
            let r2 = rng.next_f64();
            let m = [1usize, 8, 32][rng.below(3)];
            (v, r1.min(r2), r1.max(r2), m)
        },
        |&(v, rlo, rhi, m)| {
            let opts = DiscretizeOpts {
                channel_multiple: m,
                min_channels: 1,
            };
            let clo = discretize(rlo, v, opts);
            let chi = discretize(rhi, v, opts);
            if !(1..=v).contains(&clo) || !(1..=v).contains(&chi) {
                return Err(format!("out of range: {clo} {chi} of {v}"));
            }
            if chi > clo {
                return Err(format!("not monotone: r{rlo}->{clo} r{rhi}->{chi}"));
            }
            if m > 1 && clo % m != 0 && clo != v {
                return Err(format!("rounding violated: {clo} % {m}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_quant_mode_selection_total_and_bounded() {
    forall(
        Config::default(),
        |rng: &mut Pcg64| {
            (
                rng.next_f64(),
                rng.next_f64(),
                rng.below(2) == 0,
                1 + rng.below(8) as u8,
            )
        },
        |&(a, w, supported, max_bits)| {
            let mode = select_quant_mode(a, w, supported, max_bits);
            match mode {
                QuantMode::Mix { w_bits, a_bits } => {
                    if !supported {
                        return Err("MIX chosen while unsupported".into());
                    }
                    if w_bits == 0 || w_bits > max_bits || a_bits == 0 || a_bits > max_bits {
                        return Err(format!("bits out of range: w{w_bits} a{a_bits}"));
                    }
                }
                QuantMode::Int8 | QuantMode::Fp32 => {}
            }
            Ok(())
        },
    );
}

#[test]
fn prop_policy_macs_bops_consistency() {
    // For ANY policy produced by the joint mapper: macs <= total, bops <=
    // macs*32*32, bops >= macs (>=1 bit per operand).
    let ir = ir();
    let mapper = JointMapper::default();
    forall(
        Config { cases: 200, ..Default::default() },
        |rng: &mut Pcg64| {
            let mut actions = Vec::new();
            for _ in 0..ir.layers.len() {
                actions.push([rng.next_f32(), rng.next_f32(), rng.next_f32()]);
            }
            actions
        },
        |actions| {
            let mut p = DiscretePolicy::reference(&ir);
            for (i, a) in actions.iter().enumerate() {
                mapper.apply(&ir, &mut p, i, a);
            }
            let macs = p.macs(&ir);
            let bops = p.bops(&ir);
            if macs > ir.total_macs() {
                return Err(format!("macs {macs} > total {}", ir.total_macs()));
            }
            if bops > macs * 32 * 32 {
                return Err("bops exceed fp32 bound".into());
            }
            if bops < macs {
                return Err("bops below 1-bit floor".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_latency_positive_and_compression_never_hurts_much() {
    // Latency under any mapped policy stays positive and within 2x of the
    // reference (compression should never inflate cost beyond noise terms).
    let ir = ir();
    let sim = LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), 1);
    let base = sim.latency(&ir, &DiscretePolicy::reference(&ir));
    let mapper = JointMapper::default();
    forall(
        Config { cases: 200, ..Default::default() },
        |rng: &mut Pcg64| {
            (0..ir.layers.len())
                .map(|_| [rng.next_f32(), rng.next_f32(), rng.next_f32()])
                .collect::<Vec<_>>()
        },
        |actions| {
            let mut p = DiscretePolicy::reference(&ir);
            for (i, a) in actions.iter().enumerate() {
                mapper.apply(&ir, &mut p, i, a);
            }
            let lat = sim.latency(&ir, &p);
            if !(lat > 0.0) {
                return Err(format!("non-positive latency {lat}"));
            }
            if lat > base * 2.0 {
                return Err(format!("latency blew up: {lat} vs base {base}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pruning_mapper_group_safety() {
    // No action sequence may change the channel count of a dependency-
    // coupled (group) layer.
    let ir = ir();
    for mapper in [PruningMapper::default(), PruningMapper::rounded()] {
        forall(
            Config { cases: 150, ..Default::default() },
            |rng: &mut Pcg64| {
                (0..ir.layers.len())
                    .map(|_| [rng.next_f32()])
                    .collect::<Vec<_>>()
            },
            |actions| {
                let mut p = DiscretePolicy::reference(&ir);
                for (i, a) in actions.iter().enumerate() {
                    mapper.apply(&ir, &mut p, i, a);
                }
                for l in &ir.layers {
                    if !l.prunable && p.layers[l.index].kept_channels != l.cout {
                        return Err(format!("group layer {} pruned", l.name));
                    }
                }
                Ok(())
            },
        );
    }
}

#[test]
fn prop_quant_mapper_respects_hardware_support() {
    let ir = ir();
    let mapper = QuantizationMapper::default();
    let cost = CostModel::new(HwTarget::cortex_a72());
    forall(
        Config { cases: 150, ..Default::default() },
        |rng: &mut Pcg64| {
            (0..ir.layers.len())
                .map(|_| [rng.next_f32(), rng.next_f32()])
                .collect::<Vec<_>>()
        },
        |actions| {
            let mut p = DiscretePolicy::reference(&ir);
            for (i, a) in actions.iter().enumerate() {
                mapper.apply(&ir, &mut p, i, a);
            }
            // the mapper must never emit a mode the runtime would reject:
            // effective_mode must be the identity on the mapped policy
            for l in &ir.layers {
                let cin = p.effective_cin(&ir, l.index);
                let eff = cost.effective_mode(l, cin, p.layers[l.index].kept_channels, p.layers[l.index].quant);
                if eff != p.layers[l.index].quant {
                    return Err(format!(
                        "layer {}: mapper emitted {:?}, runtime runs {:?}",
                        l.name, p.layers[l.index].quant, eff
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_rng_truncated_normal_always_in_bounds() {
    forall(
        Config { cases: 300, ..Default::default() },
        |rng: &mut Pcg64| {
            (
                rng.uniform(-2.0, 3.0),
                rng.uniform(0.0, 2.0),
                rng.next_u64(),
            )
        },
        |&(mu, sigma, seed)| {
            let mut r = Pcg64::new(seed);
            for _ in 0..16 {
                let x = r.truncated_normal(mu, sigma, 0.0, 1.0);
                if !(0.0..=1.0).contains(&x) {
                    return Err(format!("sample {x} outside [0,1]"));
                }
            }
            Ok(())
        },
    );
}
