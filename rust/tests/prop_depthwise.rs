//! Property tests (via `testing::forall`) for the depthwise substrate:
//!
//! * i8 depthwise conv == f32 depthwise conv of the dequantized operands
//!   (exact up to f32 epilogue rounding), and within the analytic
//!   quantization-error bound of the true f32 conv, across randomized
//!   shapes/strides;
//! * the f32 kernel against a naive direct-convolution reference;
//! * depthwise `macs_at` / `params_at` against a naive counting reference,
//!   and strictly below dense accounting whenever it should be.

use galen::model::{Layer, LayerKind};
use galen::tensor::depthwise::{conv_dw_f32, conv_dw_i8, QuantizedDwWeights};
use galen::tensor::quant::QuantizedTensor;
use galen::tensor::Mat;
use galen::testing::{forall, Config};
use galen::util::rng::Pcg64;

/// A randomized depthwise shape: channels, spatial extent, kernel, stride.
#[derive(Debug)]
struct DwCase {
    channels: usize,
    in_sp: usize,
    kernel: usize,
    stride: usize,
    input: Vec<f32>,
    weights: Vec<f32>,
}

fn gen_case(rng: &mut Pcg64) -> DwCase {
    let channels = 1 + rng.below(24);
    let kernel = [1, 3, 5][rng.below(3)];
    let stride = 1 + rng.below(2);
    // in_sp even and >= stride so out_sp = in_sp / stride stays consistent
    // with the IR's spatial schedule
    let in_sp = 2 * (1 + rng.below(6));
    let amp = 0.25 + 4.0 * rng.next_f32();
    let input = (0..channels * in_sp * in_sp)
        .map(|_| (rng.next_f32() * 2.0 - 1.0) * amp)
        .collect();
    let weights = (0..channels * kernel * kernel)
        .map(|_| rng.next_f32() * 2.0 - 1.0)
        .collect();
    DwCase {
        channels,
        in_sp,
        kernel,
        stride,
        input,
        weights,
    }
}

/// Naive reference: direct triple loop straight from the definition,
/// structured differently from the kernel (per-output gather with explicit
/// bounds arithmetic on signed coordinates).
fn naive_dw(case: &DwCase, input: &[f32], weights: &[f32]) -> Vec<f32> {
    let (c, isp, k, s) = (case.channels, case.in_sp, case.kernel, case.stride);
    let osp = isp / s;
    let pad = (k / 2) as isize;
    let mut out = vec![0.0f32; c * osp * osp];
    for ci in 0..c {
        for oy in 0..osp {
            for ox in 0..osp {
                let mut acc = 0.0f32;
                for ky in 0..k {
                    for kx in 0..k {
                        let iy = (oy * s + ky) as isize - pad;
                        let ix = (ox * s + kx) as isize - pad;
                        if iy >= 0 && iy < isp as isize && ix >= 0 && ix < isp as isize {
                            acc += input[ci * isp * isp + iy as usize * isp + ix as usize]
                                * weights[ci * k * k + ky * k + kx];
                        }
                    }
                }
                out[ci * osp * osp + oy * osp + ox] = acc;
            }
        }
    }
    out
}

#[test]
fn f32_kernel_matches_naive_reference() {
    forall(
        Config { cases: 96, seed: 0xd3f1 },
        gen_case,
        |case| {
            let osp = case.in_sp / case.stride;
            let mut out = vec![0.0f32; case.channels * osp * osp];
            conv_dw_f32(
                &case.input,
                case.channels,
                case.in_sp,
                osp,
                case.kernel,
                case.stride,
                &case.weights,
                &mut out,
            );
            let reference = naive_dw(case, &case.input, &case.weights);
            for (i, (x, y)) in out.iter().zip(&reference).enumerate() {
                // identical accumulation order is not guaranteed vs the
                // naive loop; allow f32 reassociation slack only
                if (x - y).abs() > 1e-4 * y.abs().max(1.0) {
                    return Err(format!("[{i}] kernel {x} vs naive {y}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn i8_kernel_parity_with_f32_within_quantization_tolerance() {
    forall(
        Config { cases: 96, seed: 0x18_0a11 },
        gen_case,
        |case| {
            let osp = case.in_sp / case.stride;
            let n = case.channels * osp * osp;
            let input = Mat::from_vec(case.channels, case.in_sp * case.in_sp, case.input.clone());
            let qa = QuantizedTensor::quantize(&input);
            let qw = QuantizedDwWeights::quantize(&case.weights, case.channels, case.kernel);

            let mut qout = vec![0.0f32; n];
            conv_dw_i8(
                &qa.data, qa.scale, case.channels, case.in_sp, osp, case.stride, &qw, &mut qout,
            );

            // (a) exact parity with the f32 conv of the dequantized
            // operands: integer accumulation is exact, epilogue is one
            // multiply per element
            let mut deq = vec![0.0f32; n];
            conv_dw_f32(
                &qa.dequantize().data,
                case.channels,
                case.in_sp,
                osp,
                case.kernel,
                case.stride,
                &qw.dequantize(),
                &mut deq,
            );
            for (i, (x, y)) in qout.iter().zip(&deq).enumerate() {
                if (x - y).abs() > 1e-4 * y.abs().max(1.0) {
                    return Err(format!("[{i}] i8 {x} vs dequantized-f32 {y}"));
                }
            }

            // (b) the true f32 conv within the analytic quantization error
            // bound: each tap contributes |in_err * w| + |in~ * w_err|,
            // with per-channel weight scales and the shared input scale
            let mut full = vec![0.0f32; n];
            conv_dw_f32(
                &case.input,
                case.channels,
                case.in_sp,
                osp,
                case.kernel,
                case.stride,
                &case.weights,
                &mut full,
            );
            let taps = (case.kernel * case.kernel) as f32;
            for c in 0..case.channels {
                let taps_per = case.kernel * case.kernel;
                let w = &case.weights[c * taps_per..(c + 1) * taps_per];
                let w_max = w.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
                let in_max = case.input.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
                // half-ULP per quantized value, every tap, plus slack
                let bound = taps
                    * (0.5 * qa.scale * (w_max + 0.5 * qw.scales[c])
                        + 0.5 * qw.scales[c] * in_max)
                    * 1.01
                    + 1e-5;
                for i in 0..osp * osp {
                    let (x, y) = (qout[c * osp * osp + i], full[c * osp * osp + i]);
                    if (x - y).abs() > bound {
                        return Err(format!(
                            "channel {c} [{i}]: i8 {x} vs f32 {y} exceeds bound {bound}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// A randomized layer for the accounting property.
#[derive(Debug)]
struct AccountingCase {
    kernel: usize,
    out_spatial: usize,
    cin: usize,
    cout: usize,
}

#[test]
fn depthwise_accounting_matches_naive_reference() {
    forall(
        Config { cases: 256, seed: 0xacc7 },
        |rng| AccountingCase {
            kernel: [1, 3, 5, 7][rng.below(4)],
            out_spatial: 1 + rng.below(33),
            cin: 1 + rng.below(256),
            cout: 1 + rng.below(256),
        },
        |case| {
            let layer = |depthwise: bool| Layer {
                index: 0,
                name: "t".into(),
                kind: LayerKind::Conv,
                cin: case.cin,
                cout: case.cout,
                kernel: case.kernel,
                stride: 1,
                in_spatial: case.out_spatial,
                out_spatial: case.out_spatial,
                prunable: false,
                group: -1,
                depthwise,
            };
            let dw = layer(true);
            let dense = layer(false);

            // naive reference: one k x k filter per surviving channel,
            // applied at every output position
            let channels = case.cin.min(case.cout) as u64;
            let mut macs = 0u64;
            let mut params = 0u64;
            for _c in 0..channels {
                params += (case.kernel * case.kernel) as u64;
                for _p in 0..case.out_spatial * case.out_spatial {
                    macs += (case.kernel * case.kernel) as u64;
                }
            }
            if dw.macs_at(case.cin, case.cout) != macs {
                return Err(format!(
                    "macs_at {} vs naive {macs}",
                    dw.macs_at(case.cin, case.cout)
                ));
            }
            if dw.params_at(case.cin, case.cout) != params {
                return Err(format!(
                    "params_at {} vs naive {params}",
                    dw.params_at(case.cin, case.cout)
                ));
            }
            // depthwise < dense exactly when the dense channel cross
            // product exceeds the surviving channel count
            let dense_macs = dense.macs_at(case.cin, case.cout);
            if (case.cin as u64 * case.cout as u64) > channels
                && dw.macs_at(case.cin, case.cout) >= dense_macs
            {
                return Err(format!(
                    "depthwise {} not below dense {dense_macs}",
                    dw.macs_at(case.cin, case.cout)
                ));
            }
            // symmetry: only the surviving count matters
            if dw.macs_at(case.cin, case.cout) != dw.macs_at(case.cout, case.cin) {
                return Err("macs_at not symmetric in (cin, cout)".into());
            }
            Ok(())
        },
    );
}
