//! Shared helpers for the serve test surface: the tiny fixture session,
//! an in-process socket server harness, and a line-oriented test client.
//!
//! Compiled into each test binary that declares `mod common;` — helpers
//! unused by a given binary are expected, hence the `dead_code` allow.

#![allow(dead_code)]

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
#[cfg(unix)]
use std::os::unix::net::UnixStream;
use std::time::Duration;

use galen::coordinator::{
    serve_listener, BoundListener, NetOptions, ServeOptions, ServeStats, SERVE_PROTOCOL_VERSION,
};
use galen::eval::{SensitivityConfig, SensitivityTable};
use galen::hw::{HwTarget, LatencyKind, ProfilerConfig};
use galen::model::ir::test_fixtures::tiny_meta;
use galen::model::ModelIr;
use galen::search::LatencyFactory;
use galen::util::json::Json;

pub fn fixture() -> (ModelIr, SensitivityTable) {
    let ir = ModelIr::from_meta(&tiny_meta()).unwrap();
    let sens = SensitivityTable::disabled(ir.layers.len(), &SensitivityConfig::default(), "tiny");
    (ir, sens)
}

pub fn factory() -> LatencyFactory {
    LatencyFactory::new(
        LatencyKind::Sim,
        HwTarget::cortex_a72(),
        "tiny",
        ProfilerConfig::fast(),
        None,
    )
}

/// A submit request line for a small-but-real search job: low episode
/// count and a small agent so scripted sessions stay fast.
pub fn submit_line(id: &str, agent: &str, target: f64) -> String {
    let overrides = r#"{"episodes": 8, "warmup_episodes": 3, "opt_steps_per_episode": 4, "log_every": 0, "ddpg": {"hidden": [24, 16], "batch": 16, "replay_capacity": 200}}"#;
    format!(
        r#"{{"op":"submit","id":"{id}","spec":{{"agent":"{agent}","target":{target},"preset":"fast","config":{overrides}}}}}"#
    )
}

/// A well-formed `hello` line for this build's protocol version.
pub fn hello_line(id: &str) -> String {
    format!(r#"{{"op":"hello","id":"{id}","protocol":{SERVE_PROTOCOL_VERSION}}}"#)
}

/// Run a socket serve session around `body`: bind, serve on a scoped
/// thread, hand `body` the resolved address, then return the drained
/// session's stats alongside `body`'s result.
///
/// `body` MUST make the server exit (send `shutdown` on some connection)
/// or this blocks forever — the harness intentionally has no kill switch,
/// mirroring how `galen serve --listen` runs.
pub fn with_server<T>(
    spec: &str,
    opts: &ServeOptions,
    net: &NetOptions,
    body: impl FnOnce(&str) -> T,
) -> (ServeStats, T) {
    let (ir, sens) = fixture();
    let factory = factory();
    let listener = BoundListener::bind(spec).unwrap();
    let addr = listener.local_addr();
    let mut stats = None;
    let mut out = None;
    std::thread::scope(|scope| {
        let server =
            scope.spawn(|| serve_listener(&ir, &sens, &factory, "tiny", opts, net, listener));
        out = Some(body(&addr));
        stats = Some(server.join().expect("server thread panicked").expect("serve failed"));
    });
    (stats.unwrap(), out.unwrap())
}

/// A line-oriented protocol client over any socket stream.
pub struct Client<S: Read + Write> {
    reader: BufReader<S>,
    writer: S,
}

/// Client-side read timeout: long enough for a `result wait` on a real
/// (tiny) search job, short enough that a wedged test fails, not hangs.
const CLIENT_TIMEOUT: Duration = Duration::from_secs(120);

impl Client<TcpStream> {
    /// Connect to a TCP address (`local_addr` form: `host:port`).
    pub fn connect_tcp(addr: &str) -> Self {
        let writer = TcpStream::connect(addr)
            .unwrap_or_else(|e| panic!("connecting to {addr}: {e}"));
        writer.set_read_timeout(Some(CLIENT_TIMEOUT)).unwrap();
        writer.set_nodelay(true).unwrap();
        let reader = BufReader::new(writer.try_clone().unwrap());
        Self { reader, writer }
    }
}

#[cfg(unix)]
impl Client<UnixStream> {
    /// Connect to a Unix socket address (`local_addr` form: `unix:<path>`).
    pub fn connect_unix(addr: &str) -> Self {
        let path = addr.strip_prefix("unix:").unwrap_or(addr);
        let writer = UnixStream::connect(path)
            .unwrap_or_else(|e| panic!("connecting to {path}: {e}"));
        writer.set_read_timeout(Some(CLIENT_TIMEOUT)).unwrap();
        let reader = BufReader::new(writer.try_clone().unwrap());
        Self { reader, writer }
    }
}

impl<S: Read + Write> Client<S> {
    /// Send one request line (newline appended) and flush.
    pub fn send(&mut self, line: &str) {
        self.writer.write_all(line.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        self.writer.flush().unwrap();
    }

    /// Send raw bytes exactly as given (no newline added) and flush —
    /// for split writes, partial frames and non-UTF-8 payloads.
    pub fn send_bytes(&mut self, bytes: &[u8]) {
        self.writer.write_all(bytes).unwrap();
        self.writer.flush().unwrap();
    }

    /// Like [`Client::send`] but surfaces the write error instead of
    /// panicking — for tests that race the server's drain, where losing
    /// the connection mid-send is an expected outcome.
    pub fn try_send(&mut self, line: &str) -> std::io::Result<()> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()
    }

    /// Read one line, tolerating a dead peer: `None` on EOF *and* on read
    /// errors (a crashed server resets the connection rather than closing
    /// it cleanly).
    pub fn recv_or_dead(&mut self) -> Option<String> {
        let mut line = String::new();
        match self.reader.read_line(&mut line) {
            Ok(0) | Err(_) => None,
            Ok(_) => Some(line.trim_end_matches('\n').to_string()),
        }
    }

    /// Read one raw response line; `None` at EOF (server hung up).
    pub fn recv_raw(&mut self) -> Option<String> {
        let mut line = String::new();
        match self.reader.read_line(&mut line) {
            Ok(0) => None,
            Ok(_) => Some(line.trim_end_matches('\n').to_string()),
            Err(e) => panic!("reading response: {e}"),
        }
    }

    /// Read one response line and parse it.
    pub fn recv(&mut self) -> Json {
        let line = self.recv_raw().expect("server closed the connection mid-conversation");
        Json::parse(&line).unwrap_or_else(|e| panic!("bad response line '{line}': {e}"))
    }

    /// Lock-step request/response: one line out, one line back.
    pub fn roundtrip(&mut self, line: &str) -> Json {
        self.send(line);
        self.recv()
    }

    /// Complete the mandatory socket handshake, asserting success.
    pub fn hello(&mut self) -> Json {
        let r = self.roundtrip(&hello_line("hello"));
        assert!(r.req_bool("ok").unwrap(), "handshake refused: {}", r.dump());
        r
    }
}
