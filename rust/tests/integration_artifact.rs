//! End-to-end tests for the `.galen` deployment artifact subsystem:
//! a fixture-session search is packaged, the artifact is loaded back with
//! full verification, checked against the IR, and its latency claim is
//! re-measured through the drift gate.  The corruption matrix then proves
//! the container rejects every truncation, every sampled bit flip, stale
//! section digests, wrong schema versions, and — on signed artifacts —
//! consistently-reframed latency-claim tampering, always with a structured
//! error and never a panic.

use std::path::PathBuf;

use galen::agent::AgentKind;
use galen::artifact::{
    self, ArtifactManifest, DriftReport, LatencyClaim, PackInputs, VerifyOptions,
};
use galen::artifact::hash;
use galen::compress::{DiscretePolicy, QuantMode};
use galen::coordinator::Session;
use galen::hw::LatencyKind;
use galen::model::ModelIr;
use galen::search::{SearchConfig, SearchOutcome};
use galen::util::rng::Pcg64;

const KEY: &[u8] = b"fleet-key";

fn tmp_dir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("galen_artifact_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn fixture_session() -> Session {
    Session::fixture(LatencyKind::Sim, 7).unwrap()
}

/// A short real search on the fixture session (the artifact's normal
/// producer).
fn searched_outcome(session: &Session) -> SearchOutcome {
    let mut cfg = SearchConfig::fast(AgentKind::Joint, 0.5);
    cfg.episodes = 6;
    cfg.warmup_episodes = 2;
    session.search(&cfg).unwrap()
}

/// A deterministic mixed policy exercising all three section layouts
/// (fp32, quantized, pruned) without paying for a search.
fn mixed_policy(ir: &ModelIr) -> DiscretePolicy {
    let mut p = DiscretePolicy::reference(ir);
    for (i, l) in p.layers.iter_mut().enumerate() {
        l.quant = match i % 3 {
            0 => QuantMode::Fp32,
            1 => QuantMode::Int8,
            _ => QuantMode::Mix { w_bits: 4, a_bits: 8 },
        };
        if i % 2 == 0 {
            l.kept_channels = (l.kept_channels + 1) / 2;
        }
    }
    p
}

/// Pack `policy` on the fixture session with a claim taken from the actual
/// simulator measurement, so drift-gate assertions are meaningful.
fn packed(
    session: &Session,
    policy: &DiscretePolicy,
    key: Option<&[u8]>,
) -> (artifact::Artifact, Vec<u8>) {
    let (weights, weights_source) = session.packaging_weights().unwrap();
    let mut provider = session.latency_provider(7).unwrap();
    let claim = LatencyClaim {
        latency_s: provider.latency(&session.ir, policy),
        base_latency_s: provider.latency(&session.ir, &DiscretePolicy::reference(&session.ir)),
        backend: provider.backend().to_string(),
    };
    let art = artifact::pack(&PackInputs {
        ir: &session.ir,
        policy,
        weights: &weights,
        weights_source,
        target: &session.opts.target_hw,
        claim,
        profile_cache: "none".to_string(),
    })
    .unwrap();
    let bytes = art.encode(key);
    (art, bytes)
}

/// Rebuild a container around a (tampered) manifest, keeping the payload
/// and signature bytes and recomputing only the trailing checksum —
/// exactly what an attacker without the HMAC key can do.
fn reframe(bytes: &[u8], manifest: &ArtifactManifest) -> Vec<u8> {
    let mut mb = manifest.to_json().pretty(0).into_bytes();
    mb.push(b'\n');
    reframe_raw(bytes, &mb)
}

/// Byte-level variant of [`reframe`] for manifests that are not valid JSON.
fn reframe_raw(bytes: &[u8], manifest_bytes: &[u8]) -> Vec<u8> {
    let mlen = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
    let mend = 16 + mlen;
    let mut out = Vec::with_capacity(bytes.len());
    out.extend_from_slice(&bytes[..8]);
    out.extend_from_slice(&(manifest_bytes.len() as u64).to_le_bytes());
    out.extend_from_slice(manifest_bytes);
    out.extend_from_slice(&bytes[mend..bytes.len() - 32]);
    let checksum = hash::sha256(&out);
    out.extend_from_slice(&checksum);
    out
}

#[test]
fn packaged_search_round_trips_end_to_end() {
    let session = fixture_session();
    let outcome = searched_outcome(&session);
    let root = tmp_dir("e2e");

    let path = session.package_outcome(&outcome, &root, None).unwrap();
    assert!(path.starts_with(&root), "artifact landed outside the root: {}", path.display());
    assert_eq!(path.extension().and_then(|e| e.to_str()), Some("galen"));

    let loaded = artifact::load(&path).unwrap();
    artifact::check_against_ir(&loaded, &session.ir).unwrap();
    let m = &loaded.manifest;
    assert_eq!(m.variant, "tiny");
    assert_eq!(m.policy, outcome.best_policy);
    assert_eq!(m.claim.latency_s, outcome.best.latency_s);
    assert_eq!(m.claim.base_latency_s, outcome.base_latency_s);
    assert_eq!(m.target_fingerprint, session.opts.target_hw.fingerprint_hex());
    assert!(!loaded.signature_verified, "unsigned artifact cannot claim a verified signature");

    // the `galen run-artifact` path: re-measure and gate the claim
    let mut provider = session.latency_provider(7).unwrap();
    let measured = provider.latency(&session.ir, &m.policy);
    let report = DriftReport::new(m.claim.latency_s, measured, 0.25);
    assert!(report.within_tolerance(), "sim re-measurement drifted: {report}");

    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn packaging_is_deterministic_and_signatures_gate_loading() {
    let s1 = fixture_session();
    let outcome = searched_outcome(&s1);
    let (r1, r2, r3) = (tmp_dir("det1"), tmp_dir("det2"), tmp_dir("det3"));

    let p1 = s1.package_outcome(&outcome, &r1, None).unwrap();
    let s2 = fixture_session();
    let p2 = s2.package_outcome(&outcome, &r2, None).unwrap();
    assert_eq!(p1.file_name(), p2.file_name(), "content-addressed names must agree");
    assert_eq!(
        std::fs::read(&p1).unwrap(),
        std::fs::read(&p2).unwrap(),
        "identical inputs must produce byte-identical artifacts across sessions"
    );

    let p3 = s1.package_outcome(&outcome, &r3, Some(KEY)).unwrap();
    assert_ne!(
        std::fs::read(&p1).unwrap(),
        std::fs::read(&p3).unwrap(),
        "signing must change the bytes"
    );
    let strict = VerifyOptions { hmac_key: Some(KEY.to_vec()), require_signature: true };
    let signed = artifact::load_with(&p3, &strict).unwrap();
    assert!(signed.signature_verified);
    assert_eq!(signed.manifest.policy, outcome.best_policy);

    // wrong key and missing signature are both structured rejections
    let wrong = VerifyOptions { hmac_key: Some(b"wrong".to_vec()), require_signature: true };
    assert_eq!(artifact::load_with(&p3, &wrong).unwrap_err().stage(), "signature");
    let unsigned_strict = VerifyOptions { hmac_key: None, require_signature: true };
    assert_eq!(artifact::load_with(&p1, &unsigned_strict).unwrap_err().stage(), "signature");

    for r in [r1, r2, r3] {
        std::fs::remove_dir_all(&r).unwrap();
    }
}

#[test]
fn every_truncation_is_rejected_without_panic() {
    let session = fixture_session();
    let (_, bytes) = packed(&session, &mixed_policy(&session.ir), None);
    let opts = VerifyOptions::default();
    // every byte of the header region, then a stride through the body, then
    // every byte of the trailer region
    let mut cuts: Vec<usize> = (0..128.min(bytes.len())).collect();
    cuts.extend((128..bytes.len()).step_by(23));
    cuts.extend(bytes.len().saturating_sub(64)..bytes.len());
    for cut in cuts {
        assert!(
            artifact::verify_bytes(&bytes[..cut], &opts).is_err(),
            "truncation to {cut} of {} bytes was accepted",
            bytes.len()
        );
    }
    // trailing garbage is also a framing violation, not ignored padding
    let mut padded = bytes.clone();
    padded.push(0);
    assert!(artifact::verify_bytes(&padded, &opts).is_err());
    assert!(artifact::verify_bytes(&bytes, &opts).is_ok(), "the unmodified artifact must load");
}

#[test]
fn sampled_single_bit_flips_are_rejected_with_structured_errors() {
    let session = fixture_session();
    let (_, bytes) = packed(&session, &mixed_policy(&session.ir), Some(KEY));
    let opts = VerifyOptions { hmac_key: Some(KEY.to_vec()), require_signature: true };
    // dense over the framing-sensitive head and tail, strided over the body
    let mut offsets: Vec<usize> = (0..64).collect();
    offsets.extend((64..bytes.len()).step_by(97));
    offsets.extend(bytes.len() - 70..bytes.len());
    for (i, &off) in offsets.iter().enumerate() {
        let mut mutant = bytes.clone();
        mutant[off] ^= 1 << (i % 8);
        let err = artifact::verify_bytes(&mutant, &opts)
            .expect_err(&format!("bit flip at byte {off} was accepted"));
        assert!(!err.stage().is_empty());
        assert!(!err.to_string().is_empty(), "error at byte {off} has no message");
    }
    assert!(artifact::verify_bytes(&bytes, &opts).is_ok());
}

#[test]
fn wrong_schema_version_is_rejected_before_anything_else_is_trusted() {
    let session = fixture_session();
    let (art, bytes) = packed(&session, &mixed_policy(&session.ir), None);
    let mut m = art.manifest.clone();
    m.schema_version = 999;
    let err = artifact::verify_bytes(&reframe(&bytes, &m), &VerifyOptions::default())
        .expect_err("unknown schema version was accepted");
    assert_eq!(err.stage(), "schema", "got: {err}");
    assert!(err.to_string().contains("999"), "error must name the found version: {err}");
}

#[test]
fn stale_section_digest_is_rejected() {
    let session = fixture_session();
    let (art, bytes) = packed(&session, &mixed_policy(&session.ir), None);
    let mut m = art.manifest.clone();
    m.sections.values_mut().next().unwrap().sha256 = "0".repeat(64);
    let err = artifact::verify_bytes(&reframe(&bytes, &m), &VerifyOptions::default())
        .expect_err("stale section digest was accepted");
    assert_eq!(err.stage(), "section", "got: {err}");
}

#[test]
fn tampered_latency_claim_is_caught() {
    let session = fixture_session();
    let policy = mixed_policy(&session.ir);

    // on a signed artifact, a consistent reframe (manifest rewritten, file
    // checksum recomputed, original signature kept) dies at the signature
    let (sart, sbytes) = packed(&session, &policy, Some(KEY));
    let mut m = sart.manifest.clone();
    m.claim.latency_s *= 4.0;
    let strict = VerifyOptions { hmac_key: Some(KEY.to_vec()), require_signature: true };
    let err = artifact::verify_bytes(&reframe(&sbytes, &m), &strict)
        .expect_err("signed artifact with a rewritten claim was accepted");
    assert_eq!(err.stage(), "signature", "got: {err}");

    // an unsigned artifact cannot protect its claim cryptographically —
    // the reframe loads — but the drift gate still fails the deployment
    let (uart, ubytes) = packed(&session, &policy, None);
    let mut m = uart.manifest.clone();
    m.claim.latency_s *= 4.0;
    let loaded = artifact::verify_bytes(&reframe(&ubytes, &m), &VerifyOptions::default()).unwrap();
    let mut provider = session.latency_provider(7).unwrap();
    let measured = provider.latency(&session.ir, &loaded.manifest.policy);
    let report = DriftReport::new(loaded.manifest.claim.latency_s, measured, 0.25);
    assert!(
        !report.within_tolerance(),
        "a 4x-inflated claim must fail the drift gate: {report}"
    );
}

#[test]
fn prop_pack_verify_roundtrip_is_bit_exact() {
    let session = fixture_session();
    let (weights, weights_source) = session.packaging_weights().unwrap();
    let gen = |rng: &mut Pcg64| {
        let mut p = DiscretePolicy::reference(&session.ir);
        for (l, cmp) in session.ir.layers.iter().zip(p.layers.iter_mut()) {
            cmp.kept_channels = 1 + rng.below(l.cout);
            cmp.quant = match rng.below(3) {
                0 => QuantMode::Fp32,
                1 => QuantMode::Int8,
                _ => QuantMode::Mix { w_bits: 2 + rng.below(7) as u8, a_bits: 8 },
            };
        }
        p
    };
    galen::testing::forall(
        galen::testing::Config { cases: 24, seed: 0xA27_1F },
        gen,
        |policy| {
            let art = artifact::pack(&PackInputs {
                ir: &session.ir,
                policy,
                weights: &weights,
                weights_source: weights_source.clone(),
                target: &session.opts.target_hw,
                claim: LatencyClaim {
                    latency_s: 2.5e-3,
                    base_latency_s: 4.0e-3,
                    backend: "sim".to_string(),
                },
                profile_cache: "none".to_string(),
            })
            .map_err(|e| format!("pack failed: {e:#}"))?;
            let bytes = art.encode(None);
            let loaded = artifact::verify_bytes(&bytes, &VerifyOptions::default())
                .map_err(|e| format!("verify failed: {e}"))?;
            artifact::check_against_ir(&loaded, &session.ir)
                .map_err(|e| format!("ir check failed: {e}"))?;
            if loaded.manifest != art.manifest {
                return Err("manifest did not round-trip losslessly".to_string());
            }
            if loaded.payload != art.payload {
                return Err("payload did not round-trip bit-exactly".to_string());
            }
            let re = artifact::Artifact {
                manifest: loaded.manifest,
                payload: loaded.payload,
            }
            .encode(None);
            if re != bytes {
                return Err("re-encoding the loaded artifact changed bytes".to_string());
            }
            Ok(())
        },
    );
}
