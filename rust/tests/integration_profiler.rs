//! Measured-latency profiler integration (the PR-2 acceptance criteria):
//! an end-to-end search scored by real kernel timings, profile-cache reuse
//! with zero re-measurements, and hybrid calibration reducing the
//! simulator's relative error on held-out configurations.

use galen::agent::{AgentKind, DdpgConfig, JointMapper, PolicyMapper};
use galen::compress::DiscretePolicy;
use galen::eval::{SensitivityConfig, SensitivityTable};
use galen::hw::{
    CostModel, HwTarget, HybridProvider, LatencyProvider, LatencySimulator, MeasuredProfiler,
    ProfilerConfig,
};
use galen::model::ir::test_fixtures::tiny_meta;
use galen::model::ModelIr;
use galen::search::{run_search, SearchConfig, SimEvaluator};
use galen::util::rng::Pcg64;

fn ir() -> ModelIr {
    ModelIr::from_meta(&tiny_meta()).unwrap()
}

fn fast_profiler() -> MeasuredProfiler {
    MeasuredProfiler::new(HwTarget::cortex_a72(), "tiny", ProfilerConfig::fast())
}

fn tmp_profile_dir(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("galen_it_profiles_{tag}_{}", std::process::id()))
}

/// A small bank of random mapped policies (the joint mapper guarantees they
/// are runtime-valid).
fn random_policies(ir: &ModelIr, seed: u64, n: usize) -> Vec<DiscretePolicy> {
    let mapper = JointMapper::default();
    let mut rng = Pcg64::new(seed);
    (0..n)
        .map(|_| {
            let mut p = DiscretePolicy::reference(ir);
            for i in 0..ir.layers.len() {
                mapper.apply(
                    ir,
                    &mut p,
                    i,
                    &[rng.next_f32(), rng.next_f32(), rng.next_f32()],
                );
            }
            p
        })
        .collect()
}

#[test]
fn search_end_to_end_with_measured_profiler() {
    let ir = ir();
    let sens = SensitivityTable::disabled(ir.layers.len(), &SensitivityConfig::default(), "tiny");
    let ev = SimEvaluator::new(&ir);
    let mapper = JointMapper::default();
    let mut cfg = SearchConfig::fast(AgentKind::Joint, 0.4);
    cfg.episodes = 8;
    cfg.warmup_episodes = 2;
    cfg.log_every = 0;
    cfg.ddpg = DdpgConfig {
        hidden: (32, 24),
        batch: 24,
        replay_capacity: 400,
        ..Default::default()
    };
    let mut profiler = fast_profiler();
    let out = run_search(&ir, &sens, &ev, &mut profiler, &mapper, &cfg, None).unwrap();
    assert_eq!(out.history.len(), 8);
    assert_eq!(out.latency_backend, "measured");
    assert!(out.base_latency_s > 0.0);
    assert!(out.best.latency_s > 0.0);
    assert!(profiler.stats().measured > 0);
}

#[test]
fn second_run_hits_profile_cache_with_zero_remeasurements() {
    let ir = ir();
    let dir = tmp_profile_dir("roundtrip");
    let _ = std::fs::remove_dir_all(&dir);
    let policies = random_policies(&ir, 17, 6);

    // first run: everything must be measured, then persisted
    let mut first = MeasuredProfiler::with_cache(
        HwTarget::cortex_a72(),
        "tiny",
        ProfilerConfig::fast(),
        &dir,
    )
    .unwrap();
    let latencies: Vec<f64> = policies
        .iter()
        .map(|p| first.model_latency(&ir, p))
        .collect();
    assert!(first.stats().measured > 0);
    let path = first.save().unwrap().expect("disk-backed profiler");
    assert!(path.exists());

    // second run (fresh process simulated by a fresh profiler): everything
    // is served from the loaded manifest — zero re-measurements, identical
    // latencies down to the bit
    let mut second = MeasuredProfiler::with_cache(
        HwTarget::cortex_a72(),
        "tiny",
        ProfilerConfig::fast(),
        &dir,
    )
    .unwrap();
    assert_eq!(second.stats().loaded, first.stats().entries);
    for (p, &expect) in policies.iter().zip(&latencies) {
        assert_eq!(second.model_latency(&ir, p), expect);
    }
    let stats = second.stats();
    assert_eq!(stats.measured, 0, "cache must satisfy every configuration");
    assert!(stats.hits > 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The paper's target (a Pi 4) and this container's host differ by orders
/// of magnitude, so the analytical model's *absolute* scale is
/// systematically wrong against host measurements — which is exactly the
/// situation hybrid calibration exists for.  The slowed target pins that
/// systematic offset so the assertion below cannot go flaky when host
/// speed happens to match the modeled device.
fn slowed_target() -> HwTarget {
    let mut t = HwTarget::cortex_a72();
    t.freq_hz /= 1000.0;
    t.elemwise_per_sec /= 1000.0;
    t.pack_per_sec /= 1000.0;
    t.binary_macs_per_sec /= 1000.0;
    t.mem_bw /= 1000.0;
    t.layer_overhead_s *= 1000.0;
    t
}

#[test]
fn hybrid_calibration_reduces_mean_relative_error_on_held_out_configs() {
    let ir = ir();
    let sim = LatencySimulator::new(CostModel::new(slowed_target()), 3);
    let mut hybrid = HybridProvider::new(fast_profiler(), sim);

    // calibrate on one bank of policies...
    hybrid.calibrate(&ir, &random_policies(&ir, 23, 6));
    assert!(hybrid.is_calibrated());

    // ...evaluate on a disjoint bank, measuring each held-out layer config
    // with an independent profiler (so the hybrid's own cache cannot serve
    // them) and comparing raw vs calibrated analytical predictions.
    let mut oracle = fast_profiler();
    let cost = CostModel::new(slowed_target());
    let mut raw_err = 0.0f64;
    let mut cal_err = 0.0f64;
    let mut n = 0u32;
    for p in random_policies(&ir, 51, 4) {
        for l in &ir.layers {
            let cmp = &p.layers[l.index];
            let eff_cin = p.effective_cin(&ir, l.index);
            let meas = oracle.layer_latency(l, eff_cin, cmp.kept_channels, cmp.quant);
            let sim_raw = cost.layer_total(l, eff_cin, cmp.kept_channels, cmp.quant);
            let sim_cal =
                hybrid.calibrated_layer_total(l, eff_cin, cmp.kept_channels, cmp.quant);
            raw_err += (sim_raw - meas).abs() / meas;
            cal_err += (sim_cal - meas).abs() / meas;
            n += 1;
        }
    }
    let (raw_err, cal_err) = (raw_err / n as f64, cal_err / n as f64);
    assert!(
        cal_err < raw_err,
        "calibration must reduce mean relative error: raw {raw_err:.3} vs calibrated {cal_err:.3}"
    );
}

#[test]
fn measured_latency_responds_to_compression() {
    // Compression must reduce *measured* time, not just modeled time: the
    // pruned/quantized GEMMs are genuinely smaller/cheaper kernels.  Use
    // aggregate work (the whole fixture model) to stay above timer noise.
    let ir = ir();
    let mut prof = MeasuredProfiler::new(
        HwTarget::cortex_a72(),
        "tiny",
        ProfilerConfig {
            samples: 7,
            ..ProfilerConfig::fast()
        },
    );
    let reference = DiscretePolicy::reference(&ir);
    let base = prof.model_latency(&ir, &reference);

    let mut pruned = reference.clone();
    for l in ir.layers.iter().filter(|l| l.prunable) {
        pruned.layers[l.index].kept_channels = (l.cout / 4).max(1);
    }
    let pruned_t = prof.model_latency(&ir, &pruned);
    assert!(
        pruned_t < base,
        "4x channel pruning must measurably shrink latency: {pruned_t} vs {base}"
    );
}

#[test]
fn provider_trait_objects_are_interchangeable() {
    // The same driver code runs against all three backends.
    let ir = ir();
    let reference = DiscretePolicy::reference(&ir);
    let sim = LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), 5);
    let mut hybrid = HybridProvider::new(
        fast_profiler(),
        LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), 5),
    );
    hybrid.calibrate(&ir, &[reference.clone()]);

    let mut providers: Vec<Box<dyn LatencyProvider>> = vec![
        Box::new(sim),
        Box::new(fast_profiler()),
        Box::new(hybrid),
    ];
    let mut seen = Vec::new();
    for p in providers.iter_mut() {
        let base = p.latency(&ir, &reference);
        let m = p.measure(&ir, &reference);
        assert!(base > 0.0 && m.latency_s > 0.0, "{} backend", p.backend());
        p.persist().unwrap();
        seen.push(p.backend());
    }
    assert_eq!(seen, vec!["sim", "measured", "hybrid"]);
}
