//! Fuzz-style robustness test for `.galen` artifact loading: hundreds of
//! seeded random corruptions — truncations, bit flips, zeroed and
//! duplicated ranges, insertions, length-field rewrites, appended garbage —
//! must every one be rejected by `artifact::verify_bytes` with a structured
//! error carrying a declared stage, and must never panic or return a
//! partially-loaded artifact.  A second arm plays the stronger adversary:
//! the manifest region is mutated and the container consistently reframed
//! (lengths and trailing checksum recomputed), which the HMAC signature
//! must still catch.

use galen::artifact::{self, hash, LatencyClaim, PackInputs, VerifyOptions};
use galen::compress::{DiscretePolicy, QuantMode};
use galen::coordinator::Session;
use galen::hw::LatencyKind;
use galen::util::rng::Pcg64;

const KEY: &[u8] = b"fuzz-fleet-key";

/// One canonical signed artifact over a mixed policy on the fixture IR.
fn base_artifact() -> Vec<u8> {
    let session = Session::fixture(LatencyKind::Sim, 7).unwrap();
    let mut policy = DiscretePolicy::reference(&session.ir);
    for (i, l) in policy.layers.iter_mut().enumerate() {
        l.quant = match i % 3 {
            0 => QuantMode::Fp32,
            1 => QuantMode::Int8,
            _ => QuantMode::Mix { w_bits: 4, a_bits: 8 },
        };
        if i % 2 == 1 {
            l.kept_channels = (l.kept_channels + 1) / 2;
        }
    }
    let (weights, weights_source) = session.packaging_weights().unwrap();
    let art = artifact::pack(&PackInputs {
        ir: &session.ir,
        policy: &policy,
        weights: &weights,
        weights_source,
        target: &session.opts.target_hw,
        claim: LatencyClaim {
            latency_s: 2.0e-3,
            base_latency_s: 3.5e-3,
            backend: "sim".to_string(),
        },
        profile_cache: "none".to_string(),
    })
    .unwrap();
    art.encode(Some(KEY))
}

/// Apply one random corruption; returns a human-readable tag for failures.
fn mutate(bytes: &mut Vec<u8>, rng: &mut Pcg64) -> String {
    let len = bytes.len();
    match rng.below(7) {
        0 => {
            let cut = rng.below(len);
            bytes.truncate(cut);
            format!("truncate to {cut}")
        }
        1 => {
            let flips = 1 + rng.below(4);
            let mut tags = Vec::new();
            for _ in 0..flips {
                let off = rng.below(len);
                bytes[off] ^= 1 << rng.below(8);
                tags.push(off.to_string());
            }
            format!("flip bits at {}", tags.join(","))
        }
        2 => {
            let start = rng.below(len);
            let span = 1 + rng.below((len - start).min(64));
            bytes[start..start + span].fill(0);
            format!("zero {span} bytes at {start}")
        }
        3 => {
            let at = rng.below(len + 1);
            let n = 1 + rng.below(16);
            let junk: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
            bytes.splice(at..at, junk);
            format!("insert {n} bytes at {at}")
        }
        4 => {
            // rewrite one of the two u64 length fields (manifest length at
            // offset 8, payload length right after the manifest)
            let mlen = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
            let at = if rng.below(2) == 0 || 16 + mlen + 8 > len { 8 } else { 16 + mlen };
            let v = rng.next_u64();
            bytes[at..at + 8].copy_from_slice(&v.to_le_bytes());
            format!("length field at {at} := {v}")
        }
        5 => {
            let start = rng.below(len.saturating_sub(16));
            let span = 1 + rng.below(32.min(len - start - 1));
            let chunk = bytes[start..start + span].to_vec();
            let dst = rng.below(len - span);
            bytes[dst..dst + span].copy_from_slice(&chunk);
            format!("copy {span} bytes {start} -> {dst}")
        }
        _ => {
            let n = 1 + rng.below(32);
            bytes.extend((0..n).map(|_| rng.next_u64() as u8));
            format!("append {n} garbage bytes")
        }
    }
}

#[test]
fn fuzzed_corruptions_are_all_rejected_and_never_panic() {
    let original = base_artifact();
    let opts = VerifyOptions { hmac_key: Some(KEY.to_vec()), require_signature: true };
    assert!(artifact::verify_bytes(&original, &opts).is_ok(), "the base artifact must load");

    let mut rng = Pcg64::new(0xa27_2242);
    for case in 0..400 {
        let mut mutant = original.clone();
        let tag = mutate(&mut mutant, &mut rng);
        if mutant == original {
            continue; // e.g. zeroing a range that was already zero
        }
        let err = artifact::verify_bytes(&mutant, &opts)
            .expect_err(&format!("case {case} ({tag}) was accepted"));
        assert!(!err.stage().is_empty(), "case {case} ({tag}): empty stage");
        assert!(!err.to_string().is_empty(), "case {case} ({tag}): empty message");
    }
    // the corpus loop never corrupted shared state: the original still loads
    assert!(artifact::verify_bytes(&original, &opts).is_ok());
}

/// The stronger adversary: mutate the manifest region, then *consistently*
/// reframe the container — correct manifest length, correct payload
/// framing, recomputed trailing checksum, original signature bytes kept.
/// Only the HMAC (or, for unparseable manifests, the manifest stage) stands
/// between this and a forged latency claim.
#[test]
fn reframed_manifest_tampering_never_verifies_against_the_key() {
    let original = base_artifact();
    let opts = VerifyOptions { hmac_key: Some(KEY.to_vec()), require_signature: true };
    let mlen = u64::from_le_bytes(original[8..16].try_into().unwrap()) as usize;
    let manifest = original[16..16 + mlen].to_vec();

    let mut rng = Pcg64::new(0x5167_2242);
    for case in 0..200 {
        let mut mb = manifest.clone();
        match rng.below(3) {
            0 => {
                let off = rng.below(mb.len());
                mb[off] ^= 1 << rng.below(8);
            }
            1 => mb.truncate(1 + rng.below(mb.len())),
            _ => {
                let at = rng.below(mb.len());
                mb.splice(at..at, (0..1 + rng.below(8)).map(|_| rng.next_u64() as u8));
            }
        }
        if mb == manifest {
            continue;
        }
        // reframe: magic + new length + new manifest + untouched remainder
        // (payload, signature flag, signature), checksum recomputed
        let mut forged = Vec::with_capacity(original.len());
        forged.extend_from_slice(&original[..8]);
        forged.extend_from_slice(&(mb.len() as u64).to_le_bytes());
        forged.extend_from_slice(&mb);
        forged.extend_from_slice(&original[16 + mlen..original.len() - 32]);
        let checksum = hash::sha256(&forged);
        forged.extend_from_slice(&checksum);

        let err = artifact::verify_bytes(&forged, &opts)
            .expect_err(&format!("case {case}: a reframed manifest forgery was accepted"));
        assert!(!err.stage().is_empty(), "case {case}: empty stage");
    }
    assert!(artifact::verify_bytes(&original, &opts).is_ok());
}
