//! Driver-vs-monolith and checkpoint/resume bit-identity.
//!
//! The two acceptance guarantees of the `SearchDriver` redesign:
//!
//! 1. stepping the driver one layer decision at a time produces the exact
//!    outcome of the one-call `run_search` wrapper (same RNG streams, same
//!    order) — for all three agents;
//! 2. a search checkpointed mid-run and resumed (through an on-disk
//!    round-trip) finishes bit-identical to one that was never
//!    interrupted.

use galen::agent::{mapper_for, AgentKind, DdpgConfig};
use galen::eval::{SensitivityConfig, SensitivityTable};
use galen::hw::{CostModel, HwTarget, LatencySimulator};
use galen::model::ir::test_fixtures::tiny_meta;
use galen::model::ModelIr;
use galen::search::{
    run_search, SearchBuilder, SearchConfig, SearchDriver, SearchOutcome, SimEvaluator,
    StepOutcome,
};

fn setup() -> (ModelIr, SensitivityTable) {
    let ir = ModelIr::from_meta(&tiny_meta()).unwrap();
    let sens = SensitivityTable::disabled(ir.layers.len(), &SensitivityConfig::default(), "tiny");
    (ir, sens)
}

fn sim(seed: u64) -> LatencySimulator {
    LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), seed)
}

fn cfg(agent: AgentKind, episodes: usize) -> SearchConfig {
    let mut cfg = SearchConfig::fast(agent, 0.5);
    cfg.episodes = episodes;
    cfg.warmup_episodes = 4;
    cfg.opt_steps_per_episode = 4;
    cfg.log_every = 0;
    cfg.ddpg = DdpgConfig {
        hidden: (32, 24),
        batch: 24,
        replay_capacity: 400,
        ..Default::default()
    };
    cfg
}

/// Bitwise equality of two outcomes — `assert_eq!` on floats would accept
/// -0.0 == 0.0 etc.; the resume guarantee is stronger than that.
fn assert_outcomes_bit_identical(a: &SearchOutcome, b: &SearchOutcome, what: &str) {
    assert_eq!(a.best_policy, b.best_policy, "{what}: best policy");
    assert_eq!(a.best.episode, b.best.episode, "{what}: best episode index");
    assert_eq!(a.best.reward.to_bits(), b.best.reward.to_bits(), "{what}: best reward");
    assert_eq!(
        a.base_latency_s.to_bits(),
        b.base_latency_s.to_bits(),
        "{what}: base latency"
    );
    assert_eq!(
        a.base_accuracy.to_bits(),
        b.base_accuracy.to_bits(),
        "{what}: base accuracy"
    );
    assert_eq!(a.latency_backend, b.latency_backend, "{what}: backend label");
    assert_eq!(a.history.len(), b.history.len(), "{what}: history length");
    for (i, (x, y)) in a.history.iter().zip(&b.history).enumerate() {
        assert_eq!(x.episode, y.episode, "{what}: history[{i}].episode");
        assert_eq!(x.reward.to_bits(), y.reward.to_bits(), "{what}: history[{i}].reward");
        assert_eq!(
            x.accuracy.to_bits(),
            y.accuracy.to_bits(),
            "{what}: history[{i}].accuracy"
        );
        assert_eq!(
            x.latency_s.to_bits(),
            y.latency_s.to_bits(),
            "{what}: history[{i}].latency"
        );
        assert_eq!(x.macs, y.macs, "{what}: history[{i}].macs");
        assert_eq!(x.bops, y.bops, "{what}: history[{i}].bops");
    }
}

/// Acceptance: for every agent, a driver advanced exclusively through
/// single `step()` calls reproduces `run_search` bit for bit on the sim
/// backend.
#[test]
fn stepped_driver_matches_run_search_for_all_agents() {
    let (ir, sens) = setup();
    for agent in [AgentKind::Pruning, AgentKind::Quantization, AgentKind::Joint] {
        let cfg = cfg(agent, 14);
        let ev = SimEvaluator::new(&ir);
        let mapper = mapper_for(agent);

        let mut sim_a = sim(5);
        let legacy = run_search(&ir, &sens, &ev, &mut sim_a, mapper.as_ref(), &cfg, None).unwrap();

        let mut sim_b = sim(5);
        let mut driver = SearchBuilder::from_config(cfg.clone())
            .build(&ir, &sens, &ev, &mut sim_b, mapper.as_ref())
            .unwrap();
        let mut episodes = 0;
        let mut steps = 0;
        loop {
            match driver.step().unwrap() {
                StepOutcome::Stepped { .. } => steps += 1,
                StepOutcome::EpisodeFinished(_) => {
                    steps += 1;
                    episodes += 1;
                }
                StepOutcome::SearchComplete => break,
            }
        }
        assert_eq!(episodes, cfg.episodes, "{agent}: episode count");
        let steps_per_episode = mapper.steps(&ir).len();
        assert_eq!(steps, cfg.episodes * steps_per_episode, "{agent}: step count");
        let stepped = driver.outcome().unwrap();
        assert_outcomes_bit_identical(&stepped, &legacy, &format!("{agent} stepped-vs-monolith"));
    }
}

/// Acceptance: checkpoint at episode 6 of 16, resume through a file on
/// disk, finish — bit-identical to the uninterrupted 16-episode run.
#[test]
fn checkpoint_resume_mid_search_is_bit_identical() {
    let (ir, sens) = setup();
    let cfg = cfg(AgentKind::Quantization, 16);
    let ev = SimEvaluator::new(&ir);
    let mapper = mapper_for(AgentKind::Quantization);

    // uninterrupted reference run
    let mut sim_a = sim(9);
    let straight = run_search(&ir, &sens, &ev, &mut sim_a, mapper.as_ref(), &cfg, None).unwrap();

    // interrupted run: 6 episodes, checkpoint to disk, drop everything
    let path = std::env::temp_dir().join(format!(
        "galen_driver_ckpt_{}_{:x}.json",
        std::process::id(),
        cfg.seed
    ));
    {
        let mut sim_b = sim(9);
        let mut driver = SearchBuilder::from_config(cfg.clone())
            .build(&ir, &sens, &ev, &mut sim_b, mapper.as_ref())
            .unwrap();
        for _ in 0..6 {
            driver.run_episode().unwrap().expect("episodes remain");
        }
        assert_eq!(driver.episode(), 6);
        assert!(!driver.is_done());
        driver.write_checkpoint(&path).unwrap();
    }

    // resume in a fresh process-like context: new driver, new simulator
    // with the same seed (its noise is a pure function of (seed, policy))
    let mut sim_c = sim(9);
    let mut resumed =
        SearchDriver::resume_from_file(&path, &ir, &sens, &ev, &mut sim_c, mapper.as_ref())
            .unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(resumed.episode(), 6);
    assert_eq!(resumed.history().len(), 6);
    let out = resumed.run_to_completion().unwrap();

    assert_outcomes_bit_identical(&out, &straight, "checkpoint-resume");
}

/// A resumed driver keeps honoring the remaining episode budget and
/// re-checkpoints correctly (double interruption).
#[test]
fn double_resume_still_bit_identical() {
    let (ir, sens) = setup();
    let cfg = cfg(AgentKind::Joint, 12);
    let ev = SimEvaluator::new(&ir);
    let mapper = mapper_for(AgentKind::Joint);

    let mut sim_a = sim(13);
    let straight = run_search(&ir, &sens, &ev, &mut sim_a, mapper.as_ref(), &cfg, None).unwrap();

    // run 4, checkpoint, run 4 more, checkpoint again, finish
    let ckpt1 = {
        let mut s = sim(13);
        let mut d = SearchBuilder::from_config(cfg.clone())
            .build(&ir, &sens, &ev, &mut s, mapper.as_ref())
            .unwrap();
        for _ in 0..4 {
            d.run_episode().unwrap();
        }
        d.save_checkpoint().unwrap()
    };
    let ckpt2 = {
        let mut s = sim(13);
        let mut d = SearchDriver::resume_from(&ckpt1, &ir, &sens, &ev, &mut s, mapper.as_ref())
            .unwrap();
        for _ in 0..4 {
            d.run_episode().unwrap();
        }
        assert_eq!(d.episode(), 8);
        d.save_checkpoint().unwrap()
    };
    let mut s = sim(13);
    let out = SearchDriver::resume_from(&ckpt2, &ir, &sens, &ev, &mut s, mapper.as_ref())
        .unwrap()
        .run_to_completion()
        .unwrap();

    assert_outcomes_bit_identical(&out, &straight, "double-resume");
}

// ---------------- golden-trajectory regression fixtures ----------------

/// Bit-exact fingerprint of a search trajectory: every float as its raw
/// f64 bit pattern (hex), every counter as a hex u64 — JSON round-trips
/// cannot lose a single bit, so comparisons see exactly what the search
/// computed (how strictly they compare is `assert_fingerprints_match`'s
/// call).
fn trajectory_fingerprint(out: &SearchOutcome) -> galen::util::json::Json {
    use galen::util::json::Json;
    let episodes = out
        .history
        .iter()
        .map(|h| {
            Json::obj(vec![
                ("episode", Json::num(h.episode as f64)),
                ("reward_bits", Json::hex64(h.reward.to_bits())),
                ("accuracy_bits", Json::hex64(h.accuracy.to_bits())),
                ("latency_bits", Json::hex64(h.latency_s.to_bits())),
                ("macs", Json::hex64(h.macs)),
                ("bops", Json::hex64(h.bops)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("schema_version", Json::num(1.0)),
        ("base_latency_bits", Json::hex64(out.base_latency_s.to_bits())),
        ("best_episode", Json::num(out.best.episode as f64)),
        ("best_reward_bits", Json::hex64(out.best.reward.to_bits())),
        ("best_policy", out.best_policy.to_json()),
        ("history", Json::Arr(episodes)),
    ])
}

/// Compare a recorded fixture against a freshly computed fingerprint.
///
/// Integer fields (episode indices, MACs, BOPs, the best policy) must be
/// *exactly* equal.  Float fields compare by bit pattern first, with a
/// 1e-9 relative fallback: the trajectory runs through platform libm
/// (tanh/exp/powf/ln), whose last-ULP rounding may differ across libm
/// versions — a real trajectory shift (different RNG stream, different
/// reward math) moves these values by orders of magnitude more, so the
/// tolerance costs the fence nothing.  Same-process replay determinism is
/// asserted separately (and bit-exactly) by the double run above.
fn assert_fingerprints_match(
    golden: &galen::util::json::Json,
    fresh: &galen::util::json::Json,
    agent: AgentKind,
    path: &std::path::Path,
) {
    let float_close = |g: u64, f: u64| {
        if g == f {
            return true;
        }
        let (g, f) = (f64::from_bits(g), f64::from_bits(f));
        (g - f).abs() <= 1e-9 * g.abs().max(f.abs())
    };
    let ctx = |what: &str| {
        format!(
            "{agent}: {what} diverged from the checked-in fixture {} — if the change \
             is intentional, delete the fixture and re-run to re-record",
            path.display()
        )
    };
    assert!(
        float_close(
            golden.req_hex64("base_latency_bits").unwrap(),
            fresh.req_hex64("base_latency_bits").unwrap()
        ),
        "{}",
        ctx("base latency")
    );
    assert_eq!(
        golden.req_usize("best_episode").unwrap(),
        fresh.req_usize("best_episode").unwrap(),
        "{}",
        ctx("best episode index")
    );
    assert!(
        float_close(
            golden.req_hex64("best_reward_bits").unwrap(),
            fresh.req_hex64("best_reward_bits").unwrap()
        ),
        "{}",
        ctx("best reward")
    );
    assert_eq!(
        golden.req("best_policy").unwrap().dump(),
        fresh.req("best_policy").unwrap().dump(),
        "{}",
        ctx("best policy")
    );
    let g_eps = golden.req_arr("history").unwrap();
    let f_eps = fresh.req_arr("history").unwrap();
    assert_eq!(g_eps.len(), f_eps.len(), "{}", ctx("episode count"));
    for (k, (g, f)) in g_eps.iter().zip(f_eps).enumerate() {
        assert_eq!(
            g.req_usize("episode").unwrap(),
            f.req_usize("episode").unwrap(),
            "{}",
            ctx(&format!("history[{k}].episode"))
        );
        for field in ["reward_bits", "accuracy_bits", "latency_bits"] {
            assert!(
                float_close(g.req_hex64(field).unwrap(), f.req_hex64(field).unwrap()),
                "{}",
                ctx(&format!("history[{k}].{field}"))
            );
        }
        for field in ["macs", "bops"] {
            assert_eq!(
                g.req_hex64(field).unwrap(),
                f.req_hex64(field).unwrap(),
                "{}",
                ctx(&format!("history[{k}].{field}"))
            );
        }
    }
}

/// Golden-trajectory regression: one short search per agent kind on the
/// zoo's `micro` variant, asserted against a checked-in JSON fixture in
/// `tests/golden/` (integers/policies exactly, floats to 1e-9 — see
/// `assert_fingerprints_match`; same-process replay is asserted
/// bit-exactly).
///
/// Self-recording contract: when a fixture file is missing the test runs
/// the search twice (asserting replay determinism), records the fixture,
/// and passes — run `cargo test` once and commit the recorded files.  Once
/// committed, any refactor that silently shifts RNG streams, state
/// features, reward math, or the latency model fails this test with the
/// first diverging episode.
#[test]
fn golden_trajectories_replay_bit_identical() {
    let golden_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden");
    let ir = ModelIr::from_meta(&galen::model::zoo::meta("micro").unwrap()).unwrap();
    let sens =
        SensitivityTable::disabled(ir.layers.len(), &SensitivityConfig::default(), "micro");
    for agent in [AgentKind::Pruning, AgentKind::Quantization, AgentKind::Joint] {
        let mut cfg = cfg(agent, 6);
        cfg.warmup_episodes = 2;
        cfg.seed = 0x601d; // one fixed fixture seed for all agents
        let ev = SimEvaluator::new(&ir);
        let mapper = mapper_for(agent);

        let mut sim_a = sim(cfg.seed);
        let a = run_search(&ir, &sens, &ev, &mut sim_a, mapper.as_ref(), &cfg, None).unwrap();
        // replay determinism holds regardless of fixture presence
        let mut sim_b = sim(cfg.seed);
        let b = run_search(&ir, &sens, &ev, &mut sim_b, mapper.as_ref(), &cfg, None).unwrap();
        assert_outcomes_bit_identical(&a, &b, &format!("{agent} golden replay"));

        let fp = trajectory_fingerprint(&a);
        let path = golden_dir.join(format!("trajectory_{agent}.json"));
        if path.exists() {
            let golden = galen::util::json::Json::read_file(&path).unwrap();
            assert_fingerprints_match(&golden, &fp, agent, &path);
        } else {
            std::fs::create_dir_all(&golden_dir).unwrap();
            fp.write_file(&path).unwrap();
            eprintln!(
                "golden fixture recorded: {} — commit this file so future refactors \
                 are pinned to today's trajectory",
                path.display()
            );
        }
    }
}

/// The base-policy of sequential schemes travels inside the checkpoint.
#[test]
fn base_policy_survives_checkpoint_resume() {
    let (ir, sens) = setup();
    let cfg = cfg(AgentKind::Quantization, 8);
    let ev = SimEvaluator::new(&ir);
    let mapper = mapper_for(AgentKind::Quantization);

    let mut base = galen::compress::DiscretePolicy::reference(&ir);
    base.layers[1].kept_channels = 2;

    let ckpt = {
        let mut s = sim(3);
        let mut d = SearchBuilder::from_config(cfg.clone())
            .base_policy(base.clone())
            .build(&ir, &sens, &ev, &mut s, mapper.as_ref())
            .unwrap();
        for _ in 0..3 {
            d.run_episode().unwrap();
        }
        d.save_checkpoint().unwrap()
    };
    let mut s = sim(3);
    let out = SearchDriver::resume_from(&ckpt, &ir, &sens, &ev, &mut s, mapper.as_ref())
        .unwrap()
        .run_to_completion()
        .unwrap();
    assert_eq!(
        out.best_policy.layers[1].kept_channels, 2,
        "pruning from the base policy must survive the resumed quantization run"
    );
}
