//! Fuzz-style robustness test for the `coordinator::serve` JSONL protocol:
//! hundreds of randomized malformed / truncated / wrong-typed request lines
//! must each produce exactly one `ok:false` error response — with the
//! request's `id` echoed whenever the line parsed as a JSON object carrying
//! one — and must never panic a worker or wedge the service (a final valid
//! request still succeeds).
//!
//! The network arm replays the same hostility over a real TCP socket plus
//! the abuse only a socket can deliver: writes split mid-line and mid-UTF-8
//! sequence, slow-loris dribble, oversized lines, invalid UTF-8 frames, and
//! abrupt disconnects mid-request.

mod common;

use std::io::Cursor;

use common::{hello_line, submit_line, with_server, Client};
use galen::coordinator::{serve, NetOptions, ServeOptions, MAX_REQUEST_LINE};
use galen::eval::{SensitivityConfig, SensitivityTable};
use galen::hw::{HwTarget, LatencyKind, ProfilerConfig};
use galen::model::ir::test_fixtures::tiny_meta;
use galen::model::ModelIr;
use galen::search::LatencyFactory;
use galen::util::json::Json;
use galen::util::rng::Pcg64;

/// One generated request line plus the id we expect echoed back (None for
/// lines that are not valid JSON objects with an `id`).
struct FuzzLine {
    line: String,
    expect_id: Option<String>,
}

/// Random ASCII junk (printable, no newline) for op names and values.
fn junk(rng: &mut Pcg64, max_len: usize) -> String {
    const ALPHABET: &[u8] = b"abcdefghijklmnopqrstuvwxyz0123456789-_ {}[]:\",.";
    let n = 1 + rng.below(max_len);
    (0..n)
        .map(|_| ALPHABET[rng.below(ALPHABET.len())] as char)
        .collect()
}

/// An op name guaranteed to be unknown to the protocol (never `shutdown`,
/// which would stop the loop mid-script).
fn unknown_op(rng: &mut Pcg64) -> String {
    format!("zz-{}", junk(rng, 8).replace(['"', '{', '}', '[', ']', ':'], "x"))
}

fn gen_line(rng: &mut Pcg64, case: usize) -> FuzzLine {
    let id = format!("fz{case}");
    match rng.below(9) {
        // plain garbage: never valid JSON objects (no braces survive; the
        // leading '#' keeps the line non-empty and non-JSON)
        0 => FuzzLine {
            line: format!("#{}", junk(rng, 40).replace(['{', '}'], "#")),
            expect_id: None,
        },
        // mid-object EOF: a valid submit line truncated before its end —
        // proper prefixes of a JSON object never parse
        1 => {
            let full = format!(
                r#"{{"op":"submit","id":"{id}","spec":{{"agent":"joint","target":0.4,"preset":"fast"}}}}"#
            );
            let cut = 1 + rng.below(full.len() - 1);
            FuzzLine {
                line: full[..cut].to_string(),
                expect_id: None,
            }
        }
        // unknown op with an id: the error must echo it
        2 => FuzzLine {
            line: format!(r#"{{"op":"{}","id":"{id}"}}"#, unknown_op(rng)),
            expect_id: Some(id),
        },
        // wrong-typed op field
        3 => FuzzLine {
            line: format!(r#"{{"op":{},"id":"{id}"}}"#, rng.below(1000)),
            expect_id: Some(id),
        },
        // submit with a non-object / wrong-typed spec
        4 => FuzzLine {
            line: format!(r#"{{"op":"submit","id":"{id}","spec":{}}}"#, rng.below(100)),
            expect_id: Some(id),
        },
        // submit with bad types inside the spec (target as string, bogus
        // agent, unknown spec keys, bad config types)
        5 => {
            let spec = match rng.below(4) {
                0 => r#"{"agent":"joint","target":"half"}"#.to_string(),
                1 => r#"{"agent":"warp-drive","target":0.5}"#.to_string(),
                // the "q-" prefix guarantees the key is never a valid one
                2 => format!(
                    r#"{{"agent":"joint","target":0.5,"q-{}":1}}"#,
                    junk(rng, 6).replace(['"', '{', '}', '[', ']', ':', ',', ' ', '.'], "k")
                ),
                _ => r#"{"agent":"joint","target":0.5,"config":{"episodes":"ten"}}"#.to_string(),
            };
            FuzzLine {
                line: format!(r#"{{"op":"submit","id":"{id}","spec":{spec}}}"#),
                expect_id: Some(id),
            }
        }
        // ops aimed at jobs that do not exist / wrong-typed job field
        6 => {
            let op = ["status", "events", "result", "cancel", "forget"][rng.below(5)];
            let job = match rng.below(3) {
                0 => format!(r#""job-{}""#, 40 + rng.below(1000)),
                1 => r#""not-a-job""#.to_string(),
                _ => rng.below(50).to_string(),
            };
            FuzzLine {
                line: format!(r#"{{"op":"{op}","id":"{id}","job":{job}}}"#),
                expect_id: Some(id),
            }
        }
        // malformed metrics requests: the verb takes no operands, so any
        // extra key (the "q-" prefix keeps it unknown) or a wrong-typed id
        // must be rejected — well-formed ones would succeed and belong in
        // the integration test, not here
        7 => {
            let line = match rng.below(3) {
                0 => format!(
                    r#"{{"op":"metrics","id":"{id}","q-{}":1}}"#,
                    junk(rng, 6).replace(['"', '{', '}', '[', ']', ':', ',', ' ', '.'], "k")
                ),
                1 => format!(r#"{{"op":"metrics","id":"{id}","job":"job-1"}}"#),
                _ => format!(r#"{{"op":"metrics","id":"{id}","metrics":true}}"#),
            };
            FuzzLine { line, expect_id: Some(id) }
        }
        // valid JSON that is not an object at all
        _ => FuzzLine {
            line: match rng.below(3) {
                0 => format!("[{}]", rng.below(9)),
                1 => rng.below(1000).to_string(),
                _ => "null".to_string(),
            },
            expect_id: None,
        },
    }
}

#[test]
fn fuzzed_requests_each_get_an_error_response_and_never_wedge_the_service() {
    let ir = ModelIr::from_meta(&tiny_meta()).unwrap();
    let sens = SensitivityTable::disabled(ir.layers.len(), &SensitivityConfig::default(), "tiny");
    let factory = LatencyFactory::new(
        LatencyKind::Sim,
        HwTarget::cortex_a72(),
        "tiny",
        ProfilerConfig::fast(),
        None,
    );

    let mut rng = Pcg64::new(0xf0_2242);
    let mut lines = Vec::new();
    let mut script = String::new();
    for case in 0..300 {
        let l = gen_line(&mut rng, case);
        assert!(!l.line.trim().is_empty(), "generator produced an empty line");
        assert!(!l.line.contains('\n'), "generator produced a multi-line request");
        script.push_str(&l.line);
        script.push('\n');
        lines.push(l);
    }
    // a final valid request proves the service survived the whole barrage
    script.push_str(r#"{"op":"list","id":"survivor"}"#);
    // ... delivered without a trailing newline: the protocol loop must
    // still answer the final unterminated line (mid-stream EOF)

    let mut out = Vec::new();
    let stats = serve(
        &ir,
        &sens,
        &factory,
        "tiny",
        &ServeOptions { workers: 2, ..Default::default() },
        Cursor::new(script),
        &mut out,
    )
    .expect("the serve loop itself must not error on malformed input");

    assert_eq!(stats.submitted, 0, "no fuzz line may become a job");
    assert_eq!(stats.failed, 0);

    let text = String::from_utf8(out).unwrap();
    let responses: Vec<Json> = text
        .lines()
        .map(|l| Json::parse(l).unwrap_or_else(|e| panic!("unparseable response '{l}': {e}")))
        .collect();
    assert_eq!(
        responses.len(),
        lines.len() + 1,
        "exactly one response line per request line"
    );
    for (i, (l, r)) in lines.iter().zip(&responses).enumerate() {
        assert!(
            !r.req_bool("ok").unwrap(),
            "fuzz line {i} ({}) was accepted: {}",
            l.line,
            r.dump()
        );
        let err = r.req_str("error").unwrap_or_else(|_| panic!("line {i}: no error field"));
        assert!(!err.is_empty(), "line {i}: empty error message");
        match &l.expect_id {
            Some(id) => assert_eq!(
                r.req_str("id").ok(),
                Some(id.as_str()),
                "line {i} must echo its id: {}",
                r.dump()
            ),
            None => assert!(
                r.get("id").is_none(),
                "line {i} had no parseable id, yet one was echoed: {}",
                r.dump()
            ),
        }
    }
    let last = responses.last().unwrap();
    assert!(last.req_bool("ok").unwrap(), "service wedged: {}", last.dump());
    assert_eq!(last.req_str("id").unwrap(), "survivor");
    assert_eq!(last.req_arr("jobs").unwrap().len(), 0);
}

// ---------------------------------------------------------------------------
// Network arm: the same protocol abuse over a real TCP connection, plus the
// framing hostility only a socket can deliver.
// ---------------------------------------------------------------------------

fn net_opts() -> ServeOptions {
    ServeOptions { workers: 2, ..Default::default() }
}

/// The stdio fuzz corpus, replayed lock-step over TCP: every parseable
/// malformed line still gets exactly one `ok:false` with its id echoed
/// when it carried one, and the connection survives the whole barrage.
#[test]
fn network_fuzzed_requests_each_get_one_error_response() {
    let (stats, ()) = with_server("127.0.0.1:0", &net_opts(), &NetOptions::default(), |addr| {
        let mut client = Client::connect_tcp(addr);
        client.hello();
        let mut rng = Pcg64::new(0x7c9_2242);
        for case in 0..120 {
            let l = gen_line(&mut rng, case);
            let r = client.roundtrip(&l.line);
            assert!(
                !r.req_bool("ok").unwrap(),
                "fuzz line {case} ({}) was accepted: {}",
                l.line,
                r.dump()
            );
            assert!(!r.req_str("error").unwrap().is_empty());
            match &l.expect_id {
                Some(id) => assert_eq!(r.req_str("id").ok(), Some(id.as_str())),
                None => assert!(r.get("id").is_none(), "{}", r.dump()),
            }
        }
        let survivor = client.roundtrip(r#"{"op":"list","id":"survivor"}"#);
        assert!(survivor.req_bool("ok").unwrap(), "service wedged: {}", survivor.dump());
        assert_eq!(survivor.req_arr("jobs").unwrap().len(), 0);
        client.send(r#"{"op":"shutdown"}"#);
    });
    assert_eq!(stats.submitted, 0, "no fuzz line may become a job");
}

/// Split writes — including a flush-and-pause inside a multi-byte UTF-8
/// character — and slow-loris byte dribble must reassemble into exactly
/// one request each; the pauses straddle the server's read timeout so the
/// partial line provably survives `WouldBlock`/`TimedOut` wakeups.
#[test]
fn network_split_writes_and_slow_loris_dribble_reassemble() {
    let pause = std::time::Duration::from_millis(150); // > the server's poll interval
    let (stats, ()) = with_server("127.0.0.1:0", &net_opts(), &NetOptions::default(), |addr| {
        let mut client = Client::connect_tcp(addr);
        // the handshake itself arrives in three flushed fragments
        let hello = hello_line("frag");
        client.send_bytes(hello[..10].as_bytes());
        std::thread::sleep(pause);
        client.send_bytes(hello[10..].as_bytes());
        client.send_bytes(b"\n");
        let r = client.recv();
        assert!(r.req_bool("ok").unwrap(), "fragmented hello refused: {}", r.dump());

        // split in the middle of 'é' (0xC3 0xA9): byte-level framing must
        // hold the first half until the second arrives
        let line = r#"{"op":"list","id":"client-é"}"#.as_bytes();
        let cut = line.iter().position(|&b| b == 0xC3).unwrap() + 1;
        client.send_bytes(&line[..cut]);
        std::thread::sleep(pause);
        client.send_bytes(&line[cut..]);
        client.send_bytes(b"\n");
        let r = client.recv();
        assert!(r.req_bool("ok").unwrap(), "split-char line refused: {}", r.dump());
        assert_eq!(r.req_str("id").unwrap(), "client-é");

        // slow-loris: one byte per write, each flushed separately
        for &b in br#"{"op":"list","id":"loris"}"# {
            client.send_bytes(&[b]);
        }
        client.send_bytes(b"\n");
        let r = client.recv();
        assert!(r.req_bool("ok").unwrap(), "dribbled line refused: {}", r.dump());
        assert_eq!(r.req_str("id").unwrap(), "loris");

        client.send(r#"{"op":"shutdown"}"#);
    });
    assert_eq!(stats.submitted, 0);
}

/// An oversized line gets exactly one structured rejection without the
/// service buffering the excess, an invalid UTF-8 frame gets exactly one
/// rejection without an id echo (there is no id to recover), and the
/// connection keeps working after both.
#[test]
fn network_oversized_and_invalid_utf8_lines_recoverable() {
    let (stats, ()) = with_server("127.0.0.1:0", &net_opts(), &NetOptions::default(), |addr| {
        let mut client = Client::connect_tcp(addr);
        client.hello();

        let huge = vec![b'a'; MAX_REQUEST_LINE + 40_000];
        client.send_bytes(&huge);
        client.send_bytes(b"\n");
        let r = client.recv();
        assert!(!r.req_bool("ok").unwrap());
        assert!(
            r.req_str("error").unwrap().contains("exceeds"),
            "unexpected oversize error: {}",
            r.dump()
        );

        client.send_bytes(b"{\"op\":\"status\",\"id\":\"bin\",\"job\":\"job-\xff\"}\n");
        let r = client.recv();
        assert!(!r.req_bool("ok").unwrap());
        assert!(
            r.req_str("error").unwrap().contains("utf-8"),
            "unexpected utf-8 error: {}",
            r.dump()
        );
        assert!(r.get("id").is_none(), "an unreadable line cannot echo an id");

        let r = client.roundtrip(r#"{"op":"list","id":"after"}"#);
        assert!(r.req_bool("ok").unwrap(), "stream did not recover: {}", r.dump());
        assert_eq!(r.req_str("id").unwrap(), "after");

        client.send(r#"{"op":"shutdown"}"#);
    });
    assert_eq!(stats.submitted, 0);
}

/// A client vanishing mid-request takes down neither the service nor the
/// job it already submitted: a second client finishes its own work and the
/// orphaned job still runs to completion.
#[test]
fn network_abrupt_disconnect_mid_request_leaves_service_serving() {
    let (stats, ()) = with_server("127.0.0.1:0", &net_opts(), &NetOptions::default(), |addr| {
        let (orphan_job, orphan_token) = {
            let mut doomed = Client::connect_tcp(addr);
            doomed.hello();
            let r = doomed.roundtrip(&submit_line("doomed", "quantization", 0.5));
            assert!(r.req_bool("ok").unwrap(), "{}", r.dump());
            let job = r.req_str("job").unwrap().to_string();
            let token = r.req_str("token").unwrap().to_string();
            // half a request, never finished: the connection drops here
            doomed.send_bytes(b"{\"op\":\"status\",\"id\":\"never");
            (job, token)
        };
        let mut client = Client::connect_tcp(addr);
        client.hello();
        let r = client.roundtrip(&submit_line("mine", "quantization", 0.4));
        assert!(r.req_bool("ok").unwrap(), "{}", r.dump());
        let my_job = r.req_str("job").unwrap().to_string();
        let r = client
            .roundtrip(&format!(r#"{{"op":"result","id":"rw","job":"{my_job}","wait":true}}"#));
        assert_eq!(r.req_str("state").unwrap(), "done", "{}", r.dump());
        // the orphan keeps running under its own steam; its token is the
        // only key the dead connection left behind
        let r = client.roundtrip(&format!(
            r#"{{"op":"result","id":"ro","job":"{orphan_job}","token":"{orphan_token}","wait":true}}"#
        ));
        assert_eq!(r.req_str("state").unwrap(), "done", "{}", r.dump());
        client.send(r#"{"op":"shutdown"}"#);
    });
    assert_eq!(stats.submitted, 2);
    assert_eq!(stats.completed, 2);
    assert_eq!(stats.failed, 0);
}
