//! Fuzz-style robustness test for the `coordinator::serve` JSONL protocol:
//! hundreds of randomized malformed / truncated / wrong-typed request lines
//! must each produce exactly one `ok:false` error response — with the
//! request's `id` echoed whenever the line parsed as a JSON object carrying
//! one — and must never panic a worker or wedge the service (a final valid
//! request still succeeds).

use std::io::Cursor;

use galen::coordinator::{serve, ServeOptions};
use galen::eval::{SensitivityConfig, SensitivityTable};
use galen::hw::{HwTarget, LatencyKind, ProfilerConfig};
use galen::model::ir::test_fixtures::tiny_meta;
use galen::model::ModelIr;
use galen::search::LatencyFactory;
use galen::util::json::Json;
use galen::util::rng::Pcg64;

/// One generated request line plus the id we expect echoed back (None for
/// lines that are not valid JSON objects with an `id`).
struct FuzzLine {
    line: String,
    expect_id: Option<String>,
}

/// Random ASCII junk (printable, no newline) for op names and values.
fn junk(rng: &mut Pcg64, max_len: usize) -> String {
    const ALPHABET: &[u8] = b"abcdefghijklmnopqrstuvwxyz0123456789-_ {}[]:\",.";
    let n = 1 + rng.below(max_len);
    (0..n)
        .map(|_| ALPHABET[rng.below(ALPHABET.len())] as char)
        .collect()
}

/// An op name guaranteed to be unknown to the protocol (never `shutdown`,
/// which would stop the loop mid-script).
fn unknown_op(rng: &mut Pcg64) -> String {
    format!("zz-{}", junk(rng, 8).replace(['"', '{', '}', '[', ']', ':'], "x"))
}

fn gen_line(rng: &mut Pcg64, case: usize) -> FuzzLine {
    let id = format!("fz{case}");
    match rng.below(9) {
        // plain garbage: never valid JSON objects (no braces survive; the
        // leading '#' keeps the line non-empty and non-JSON)
        0 => FuzzLine {
            line: format!("#{}", junk(rng, 40).replace(['{', '}'], "#")),
            expect_id: None,
        },
        // mid-object EOF: a valid submit line truncated before its end —
        // proper prefixes of a JSON object never parse
        1 => {
            let full = format!(
                r#"{{"op":"submit","id":"{id}","spec":{{"agent":"joint","target":0.4,"preset":"fast"}}}}"#
            );
            let cut = 1 + rng.below(full.len() - 1);
            FuzzLine {
                line: full[..cut].to_string(),
                expect_id: None,
            }
        }
        // unknown op with an id: the error must echo it
        2 => FuzzLine {
            line: format!(r#"{{"op":"{}","id":"{id}"}}"#, unknown_op(rng)),
            expect_id: Some(id),
        },
        // wrong-typed op field
        3 => FuzzLine {
            line: format!(r#"{{"op":{},"id":"{id}"}}"#, rng.below(1000)),
            expect_id: Some(id),
        },
        // submit with a non-object / wrong-typed spec
        4 => FuzzLine {
            line: format!(r#"{{"op":"submit","id":"{id}","spec":{}}}"#, rng.below(100)),
            expect_id: Some(id),
        },
        // submit with bad types inside the spec (target as string, bogus
        // agent, unknown spec keys, bad config types)
        5 => {
            let spec = match rng.below(4) {
                0 => r#"{"agent":"joint","target":"half"}"#.to_string(),
                1 => r#"{"agent":"warp-drive","target":0.5}"#.to_string(),
                // the "q-" prefix guarantees the key is never a valid one
                2 => format!(
                    r#"{{"agent":"joint","target":0.5,"q-{}":1}}"#,
                    junk(rng, 6).replace(['"', '{', '}', '[', ']', ':', ',', ' ', '.'], "k")
                ),
                _ => r#"{"agent":"joint","target":0.5,"config":{"episodes":"ten"}}"#.to_string(),
            };
            FuzzLine {
                line: format!(r#"{{"op":"submit","id":"{id}","spec":{spec}}}"#),
                expect_id: Some(id),
            }
        }
        // ops aimed at jobs that do not exist / wrong-typed job field
        6 => {
            let op = ["status", "events", "result", "cancel", "forget"][rng.below(5)];
            let job = match rng.below(3) {
                0 => format!(r#""job-{}""#, 40 + rng.below(1000)),
                1 => r#""not-a-job""#.to_string(),
                _ => rng.below(50).to_string(),
            };
            FuzzLine {
                line: format!(r#"{{"op":"{op}","id":"{id}","job":{job}}}"#),
                expect_id: Some(id),
            }
        }
        // malformed metrics requests: the verb takes no operands, so any
        // extra key (the "q-" prefix keeps it unknown) or a wrong-typed id
        // must be rejected — well-formed ones would succeed and belong in
        // the integration test, not here
        7 => {
            let line = match rng.below(3) {
                0 => format!(
                    r#"{{"op":"metrics","id":"{id}","q-{}":1}}"#,
                    junk(rng, 6).replace(['"', '{', '}', '[', ']', ':', ',', ' ', '.'], "k")
                ),
                1 => format!(r#"{{"op":"metrics","id":"{id}","job":"job-1"}}"#),
                _ => format!(r#"{{"op":"metrics","id":"{id}","metrics":true}}"#),
            };
            FuzzLine { line, expect_id: Some(id) }
        }
        // valid JSON that is not an object at all
        _ => FuzzLine {
            line: match rng.below(3) {
                0 => format!("[{}]", rng.below(9)),
                1 => rng.below(1000).to_string(),
                _ => "null".to_string(),
            },
            expect_id: None,
        },
    }
}

#[test]
fn fuzzed_requests_each_get_an_error_response_and_never_wedge_the_service() {
    let ir = ModelIr::from_meta(&tiny_meta()).unwrap();
    let sens = SensitivityTable::disabled(ir.layers.len(), &SensitivityConfig::default(), "tiny");
    let factory = LatencyFactory::new(
        LatencyKind::Sim,
        HwTarget::cortex_a72(),
        "tiny",
        ProfilerConfig::fast(),
        None,
    );

    let mut rng = Pcg64::new(0xf0_2242);
    let mut lines = Vec::new();
    let mut script = String::new();
    for case in 0..300 {
        let l = gen_line(&mut rng, case);
        assert!(!l.line.trim().is_empty(), "generator produced an empty line");
        assert!(!l.line.contains('\n'), "generator produced a multi-line request");
        script.push_str(&l.line);
        script.push('\n');
        lines.push(l);
    }
    // a final valid request proves the service survived the whole barrage
    script.push_str(r#"{"op":"list","id":"survivor"}"#);
    // ... delivered without a trailing newline: the protocol loop must
    // still answer the final unterminated line (mid-stream EOF)

    let mut out = Vec::new();
    let stats = serve(
        &ir,
        &sens,
        &factory,
        "tiny",
        &ServeOptions { workers: 2, ..Default::default() },
        Cursor::new(script),
        &mut out,
    )
    .expect("the serve loop itself must not error on malformed input");

    assert_eq!(stats.submitted, 0, "no fuzz line may become a job");
    assert_eq!(stats.failed, 0);

    let text = String::from_utf8(out).unwrap();
    let responses: Vec<Json> = text
        .lines()
        .map(|l| Json::parse(l).unwrap_or_else(|e| panic!("unparseable response '{l}': {e}")))
        .collect();
    assert_eq!(
        responses.len(),
        lines.len() + 1,
        "exactly one response line per request line"
    );
    for (i, (l, r)) in lines.iter().zip(&responses).enumerate() {
        assert!(
            !r.req_bool("ok").unwrap(),
            "fuzz line {i} ({}) was accepted: {}",
            l.line,
            r.dump()
        );
        let err = r.req_str("error").unwrap_or_else(|_| panic!("line {i}: no error field"));
        assert!(!err.is_empty(), "line {i}: empty error message");
        match &l.expect_id {
            Some(id) => assert_eq!(
                r.req_str("id").ok(),
                Some(id.as_str()),
                "line {i} must echo its id: {}",
                r.dump()
            ),
            None => assert!(
                r.get("id").is_none(),
                "line {i} had no parseable id, yet one was echoed: {}",
                r.dump()
            ),
        }
    }
    let last = responses.last().unwrap();
    assert!(last.req_bool("ok").unwrap(), "service wedged: {}", last.dump());
    assert_eq!(last.req_str("id").unwrap(), "survivor");
    assert_eq!(last.req_arr("jobs").unwrap().len(), 0);
}
