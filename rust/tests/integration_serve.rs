//! End-to-end `coordinator::serve` sessions over in-memory JSONL pipes:
//! the exact protocol `galen serve` speaks on stdin/stdout.

use std::io::Cursor;

use galen::coordinator::{serve, ServeOptions};
use galen::eval::{SensitivityConfig, SensitivityTable};
use galen::hw::{HwTarget, LatencyKind, ProfilerConfig};
use galen::model::ir::test_fixtures::tiny_meta;
use galen::model::ModelIr;
use galen::search::LatencyFactory;
use galen::util::json::Json;

fn fixture() -> (ModelIr, SensitivityTable) {
    let ir = ModelIr::from_meta(&tiny_meta()).unwrap();
    let sens = SensitivityTable::disabled(ir.layers.len(), &SensitivityConfig::default(), "tiny");
    (ir, sens)
}

fn factory() -> LatencyFactory {
    LatencyFactory::new(
        LatencyKind::Sim,
        HwTarget::cortex_a72(),
        "tiny",
        ProfilerConfig::fast(),
        None,
    )
}

/// A submit request line for a small-but-real search job: low episode
/// count and a small agent so the whole scripted session stays fast.
fn submit_line(id: &str, agent: &str, target: f64) -> String {
    let overrides = r#"{"episodes": 8, "warmup_episodes": 3, "opt_steps_per_episode": 4, "log_every": 0, "ddpg": {"hidden": [24, 16], "batch": 16, "replay_capacity": 200}}"#;
    format!(
        r#"{{"op":"submit","id":"{id}","spec":{{"agent":"{agent}","target":{target},"preset":"fast","config":{overrides}}}}}"#
    )
}

fn run_session(script: &str, opts: &ServeOptions) -> (galen::coordinator::ServeStats, Vec<Json>) {
    let (ir, sens) = fixture();
    let factory = factory();
    let mut out = Vec::new();
    let stats = serve(
        &ir,
        &sens,
        &factory,
        "tiny",
        opts,
        Cursor::new(script.to_string()),
        &mut out,
    )
    .unwrap();
    let text = String::from_utf8(out).unwrap();
    let responses: Vec<Json> = text
        .lines()
        .map(|l| Json::parse(l).unwrap_or_else(|e| panic!("bad response line '{l}': {e}")))
        .collect();
    (stats, responses)
}

/// The acceptance-criteria session: submit 2 jobs, wait on both results,
/// page the event stream — both jobs complete and both artifacts land.
#[test]
fn scripted_two_job_session_completes_with_artifacts() {
    let dir = std::env::temp_dir().join(format!("galen_serve_it_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let script = format!(
        "{}\n{}\n{}\n{}\n{}\n{}\n{}\n{}\n",
        submit_line("a", "quantization", 0.5),
        submit_line("b", "joint", 0.4),
        r#"{"op":"result","id":"ra","job":"job-0","wait":true}"#,
        r#"{"op":"result","id":"rb","job":"job-1","wait":true}"#,
        r#"{"op":"events","id":"ev","job":"job-0"}"#,
        r#"{"op":"forget","id":"fg","job":"job-0"}"#,
        r#"{"op":"events","id":"ev2","job":"job-0"}"#,
        r#"{"op":"list","id":"ls"}"#,
    );
    let opts = ServeOptions {
        workers: 2,
        results_dir: Some(dir.clone()),
        ..Default::default()
    };
    let (stats, responses) = run_session(&script, &opts);

    assert_eq!(stats.submitted, 2);
    assert_eq!(stats.completed, 2);
    assert_eq!(stats.failed, 0);
    assert_eq!(responses.len(), 8, "one response line per request line");
    for r in &responses {
        assert!(r.req_bool("ok").unwrap(), "{}", r.dump());
    }
    // submits echo ids and hand out job names
    assert_eq!(responses[0].req_str("id").unwrap(), "a");
    assert_eq!(responses[0].req_str("job").unwrap(), "job-0");
    assert_eq!(responses[1].req_str("job").unwrap(), "job-1");

    // both waited results are done and carry an outcome + policy + artifact
    for (r, job) in [(&responses[2], "job-0"), (&responses[3], "job-1")] {
        assert_eq!(r.req_str("state").unwrap(), "done", "{}", r.dump());
        assert_eq!(r.req_str("job").unwrap(), job);
        let outcome = r.req("outcome").unwrap();
        assert_eq!(outcome.req("history").unwrap().as_arr().unwrap().len(), 8);
        assert!(outcome.req_f64("base_latency_s").unwrap() > 0.0);
        assert!(!r.req_arr("policy").unwrap().is_empty());
        assert!(r.req_str("artifact").unwrap().contains(job));
    }

    // the event stream saw the whole search: started + 8 episodes + finished
    let events = responses[4].req_arr("events").unwrap();
    let types: Vec<&str> = events.iter().map(|e| e.req_str("type").unwrap()).collect();
    assert_eq!(types.first().copied(), Some("started"));
    assert_eq!(types.last().copied(), Some("finished"));
    assert_eq!(types.iter().filter(|t| **t == "episode").count(), 8);
    assert!(types.contains(&"best"));
    assert_eq!(
        responses[4].req_usize("next").unwrap(),
        events.len(),
        "cursor points past the returned events"
    );

    // forget frees job-0's events/outcome but keeps its status line
    assert_eq!(responses[5].req_str("state").unwrap(), "done");
    assert!(responses[6].req_arr("events").unwrap().is_empty());
    assert_eq!(responses[6].req_usize("next").unwrap(), 0);

    // list sees both jobs as done (forgotten or not)
    let jobs = responses[7].req_arr("jobs").unwrap();
    assert_eq!(jobs.len(), 2);
    assert!(jobs.iter().all(|j| j.req_str("state").unwrap() == "done"));

    // both result records were written
    for job in ["job-0", "job-1"] {
        let path = dir.join(format!("serve_tiny_{job}.json"));
        assert!(path.exists(), "missing artifact {}", path.display());
        let rec = Json::read_file(&path).unwrap();
        assert_eq!(rec.req_str("name").unwrap(), format!("serve_tiny_{job}"));
        rec.req("outcome").unwrap();
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// On stdio the `hello` handshake is optional (pipeline scripts pre-date
/// it) but fully supported: a correct hello succeeds, a mismatch is
/// refused with both versions echoed, and ops work regardless.
#[test]
fn stdio_hello_is_optional_but_supported() {
    use galen::coordinator::SERVE_PROTOCOL_VERSION;
    let hello_ok = format!(r#"{{"op":"hello","id":"h","protocol":{SERVE_PROTOCOL_VERSION}}}"#);
    let script = format!(
        "{}\n{}\n{}\n",
        // ops before any hello work on stdio — no handshake gate here
        r#"{"op":"list","id":"pre"}"#,
        hello_ok,
        r#"{"op":"hello","id":"old","protocol":1}"#,
    );
    let (_, responses) = run_session(
        &script,
        &ServeOptions { workers: 1, ..Default::default() },
    );
    assert!(responses[0].req_bool("ok").unwrap());
    assert!(responses[1].req_bool("ok").unwrap());
    assert_eq!(
        responses[1].req_usize("protocol").unwrap(),
        SERVE_PROTOCOL_VERSION
    );
    assert!(responses[1].req_arr("capabilities").unwrap().len() >= 10);
    assert_eq!(responses[1].req_str("variant").unwrap(), "tiny");
    assert!(!responses[2].req_bool("ok").unwrap());
    assert_eq!(responses[2].req_usize("client_protocol").unwrap(), 1);
    assert_eq!(
        responses[2].req_usize("server_protocol").unwrap(),
        SERVE_PROTOCOL_VERSION
    );
}

/// Every accepted submit hands back the job's access token: 16 hex chars,
/// stable for a given (seed, job index) so a resumed session re-derives
/// the tokens its clients already hold.
#[test]
fn submit_response_carries_a_deterministic_job_token() {
    let script = format!(
        "{}\n{}\n",
        submit_line("a", "quantization", 0.5),
        r#"{"op":"cancel","job":"job-0"}"#,
    );
    let opts = ServeOptions { workers: 1, ..Default::default() };
    let (_, first) = run_session(&script, &opts);
    let (_, second) = run_session(&script, &opts);
    for responses in [&first, &second] {
        let token = responses[0].req_str("token").unwrap();
        assert_eq!(token.len(), 16, "{token}");
        assert!(token.chars().all(|c| c.is_ascii_hexdigit()), "{token}");
    }
    assert_eq!(
        first[0].req_str("token").unwrap(),
        second[0].req_str("token").unwrap(),
        "same seed + same index must derive the same token"
    );
}

/// Events paging: `since` continues where the previous fetch stopped.
#[test]
fn events_cursor_pages_incrementally() {
    let script = format!(
        "{}\n{}\n{}\n",
        submit_line("s", "pruning", 0.6),
        r#"{"op":"result","job":"job-0","wait":true}"#,
        r#"{"op":"events","job":"job-0","since":3}"#,
    );
    let (_, responses) = run_session(
        &script,
        &ServeOptions { workers: 1, ..Default::default() },
    );
    let page = &responses[2];
    let next = page.req_usize("next").unwrap();
    let events = page.req_arr("events").unwrap();
    // started + 8 episodes + >=1 best + finished, minus the 3 skipped
    assert_eq!(events.len(), next - 3);
    assert!(next >= 10);
}

/// A queued job cancelled before any worker reaches it terminates as
/// `cancelled` (the single worker is busy with the long first job).
#[test]
fn cancel_queued_job_terminates_without_running() {
    let script = format!(
        "{}\n{}\n{}\n{}\n",
        submit_line("c0", "joint", 0.4),
        submit_line("c1", "pruning", 0.5),
        r#"{"op":"cancel","job":"job-1"}"#,
        r#"{"op":"result","job":"job-1","wait":true}"#,
    );
    let (stats, responses) = run_session(
        &script,
        &ServeOptions { workers: 1, ..Default::default() },
    );
    assert_eq!(stats.submitted, 2);
    assert_eq!(stats.cancelled, 1);
    assert_eq!(stats.completed, 1, "the running job still finishes");
    assert_eq!(responses[3].req_str("state").unwrap(), "cancelled");
}

/// Protocol robustness: bad requests answer with ok=false and never take
/// the service down; good requests after them still work.
#[test]
fn bad_requests_get_error_responses() {
    let script = format!(
        "{}\n{}\n{}\n{}\n{}\n{}\n",
        "this is not json",
        r#"{"op":"frobnicate"}"#,
        r#"{"op":"status","job":"job-99"}"#,
        r#"{"op":"submit","spec":{"agent":"warp","target":0.5}}"#,
        submit_line("ok", "pruning", 0.5),
        r#"{"op":"result","job":"job-0","wait":true}"#,
    );
    let (stats, responses) = run_session(
        &script,
        &ServeOptions { workers: 1, ..Default::default() },
    );
    assert_eq!(responses.len(), 6);
    assert!(!responses[0].req_bool("ok").unwrap());
    assert!(!responses[1].req_bool("ok").unwrap());
    assert!(responses[1].req_str("error").unwrap().contains("frobnicate"));
    assert!(!responses[2].req_bool("ok").unwrap());
    assert!(responses[2].req_str("error").unwrap().contains("job-99"));
    assert!(!responses[3].req_bool("ok").unwrap());
    // the service kept going: the good job completes
    assert_eq!(responses[5].req_str("state").unwrap(), "done");
    assert_eq!(stats.submitted, 1, "rejected submits never became jobs");
    assert_eq!(stats.completed, 1);
}

/// The optional spec `variant` pins a submit to one model: matching the
/// served variant is accepted, a mismatch is rejected with a message
/// naming both sides (a serve process hosts exactly one model).
#[test]
fn submit_variant_assertion_matches_served_model() {
    let script = concat!(
        r#"{"op":"submit","id":"v1","spec":{"agent":"pruning","target":0.5,"variant":"tiny","preset":"fast","config":{"episodes":4,"warmup_episodes":2,"log_every":0,"ddpg":{"hidden":[24,16],"batch":16,"replay_capacity":200}}}}"#,
        "\n",
        r#"{"op":"submit","id":"v2","spec":{"agent":"pruning","target":0.5,"variant":"mobilenetv2s"}}"#,
        "\n",
        r#"{"op":"result","id":"rv","job":"job-0","wait":true}"#,
        "\n"
    );
    let (stats, responses) = run_session(
        script,
        &ServeOptions { workers: 1, ..Default::default() },
    );
    assert!(responses[0].req_bool("ok").unwrap(), "{}", responses[0].dump());
    assert!(!responses[1].req_bool("ok").unwrap());
    assert_eq!(responses[1].req_str("id").unwrap(), "v2");
    let err = responses[1].req_str("error").unwrap();
    assert!(err.contains("mobilenetv2s") && err.contains("tiny"), "{err}");
    assert_eq!(stats.submitted, 1);
    assert_eq!(responses[2].req_str("state").unwrap(), "done");
}

/// Journal replay across sessions: a journaled session's finished job is
/// restored as a status record by `--resume-jobs`, new submissions continue
/// the id sequence, and a cleanly-finished journal is cleared by the next
/// plain (non-resuming) session.
#[test]
fn journal_restores_finished_jobs_across_sessions() {
    let dir = std::env::temp_dir().join(format!("galen_serve_journal_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();

    let script1 = format!(
        "{}\n{}\n",
        submit_line("a", "pruning", 0.5),
        r#"{"op":"result","job":"job-0","wait":true}"#,
    );
    let opts1 = ServeOptions {
        workers: 1,
        journal_dir: Some(dir.clone()),
        ..Default::default()
    };
    let (stats1, _) = run_session(&script1, &opts1);
    assert_eq!(stats1.completed, 1);
    assert!(dir.join("serve_journal.jsonl").exists());

    // session 2 resumes: job-0 is a restored status record, a new submit
    // continues the id sequence at job-1
    let script2 = format!(
        "{}\n{}\n{}\n",
        r#"{"op":"list","id":"ls"}"#,
        submit_line("b", "joint", 0.4),
        r#"{"op":"result","job":"job-1","wait":true}"#,
    );
    let opts2 = ServeOptions {
        workers: 1,
        journal_dir: Some(dir.clone()),
        resume_jobs: true,
        ..Default::default()
    };
    let (stats2, responses2) = run_session(&script2, &opts2);
    let jobs = responses2[0].req_arr("jobs").unwrap();
    assert_eq!(jobs.len(), 1, "the finished job survives as a status row");
    assert_eq!(jobs[0].req_str("job").unwrap(), "job-0");
    assert_eq!(jobs[0].req_str("state").unwrap(), "done");
    assert_eq!(responses2[1].req_str("job").unwrap(), "job-1");
    assert_eq!(responses2[2].req_str("state").unwrap(), "done");
    assert_eq!(stats2.submitted, 1, "restored jobs are not this session's work");
    assert_eq!(stats2.resumed, 0);
    assert_eq!(stats2.completed, 1);

    // session 3 without --resume-jobs: every journaled job is terminal, so
    // the stale journal is cleared and ids restart from job-0
    let script3 = format!(
        "{}\n{}\n",
        submit_line("c", "pruning", 0.6),
        r#"{"op":"result","job":"job-0","wait":true}"#,
    );
    let (stats3, responses3) = run_session(&script3, &opts1);
    assert_eq!(responses3[0].req_str("job").unwrap(), "job-0");
    assert_eq!(stats3.completed, 1);

    std::fs::remove_dir_all(&dir).ok();
}

/// The `metrics` verb returns a live schema-versioned registry snapshot:
/// ok=true with the id echoed, parseable by `MetricsSnapshot::from_json`,
/// and — because the waited job finished before the request was handled —
/// it already contains the serve-side counters and per-verb latency
/// histograms.  Counters are process-global and tests run concurrently,
/// so assertions are lower bounds, never exact counts.
#[test]
fn metrics_verb_returns_parseable_registry_snapshot() {
    let script = format!(
        "{}\n{}\n{}\n",
        submit_line("m", "pruning", 0.5),
        r#"{"op":"result","id":"rm","job":"job-0","wait":true}"#,
        r#"{"op":"metrics","id":"mx"}"#,
    );
    let (stats, responses) = run_session(
        &script,
        &ServeOptions { workers: 1, ..Default::default() },
    );
    assert_eq!(stats.completed, 1);
    let r = &responses[2];
    assert!(r.req_bool("ok").unwrap(), "{}", r.dump());
    assert_eq!(r.req_str("id").unwrap(), "mx");

    let body = r.req("metrics").unwrap();
    assert_eq!(body.req_usize("schema_version").unwrap(), 1);
    let snap = galen::obs::MetricsSnapshot::from_json(body)
        .expect("the wire snapshot must parse with this build's schema");
    assert!(
        snap.counter("serve_jobs_completed_total").unwrap_or(0) >= 1,
        "the finished job must be visible: {snap:?}"
    );
    assert!(
        snap.histograms
            .contains_key(r#"serve_request_seconds{verb="submit"}"#),
        "per-verb request latency must be recorded: {snap:?}"
    );
}

/// Unknown keys in a submit spec — at the spec level and inside its
/// `config` block — are rejected loudly (the apply_json contract reaches
/// the protocol surface), and failing requests still echo their id.
#[test]
fn submit_rejects_unknown_keys_at_both_levels() {
    let script = concat!(
        r#"{"op":"submit","id":"k1","spec":{"agent":"joint","target":0.4,"config":{"episdoes": 5}}}"#,
        "\n",
        r#"{"op":"submit","id":"k2","spec":{"agent":"joint","target":0.4,"cofig":{"episodes": 5}}}"#,
        "\n"
    );
    let (stats, responses) = run_session(
        script,
        &ServeOptions { workers: 1, ..Default::default() },
    );
    assert_eq!(stats.submitted, 0);

    assert!(!responses[0].req_bool("ok").unwrap());
    assert_eq!(responses[0].req_str("id").unwrap(), "k1", "errors must echo the id");
    let err = responses[0].req_str("error").unwrap();
    assert!(err.contains("episdoes"), "{err}");
    assert!(err.contains("episodes"), "must list valid keys: {err}");

    assert!(!responses[1].req_bool("ok").unwrap());
    assert_eq!(responses[1].req_str("id").unwrap(), "k2");
    let err = responses[1].req_str("error").unwrap();
    assert!(err.contains("cofig"), "{err}");
    assert!(err.contains("config"), "must list valid spec keys: {err}");
}
