//! Integration tests over the real AOT artifacts (run `make artifacts`
//! first; tests are skipped with a notice when artifacts are absent).
//!
//! These exercise the full L3->L2->L1 composition: HLO-text loading, PJRT
//! compilation, input packing (params + policy), masked/quantized forward,
//! the Pallas-kernel artifact, and the train-step graph.

use std::path::PathBuf;

use galen::compress::{DiscretePolicy, QuantMode};
use galen::eval::{Evaluator, Split};
use galen::runtime::{ArtifactRegistry, HostTensor, PjrtRuntime};

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("meta_micro.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built");
        None
    }
}

fn evaluator(variant: &str) -> Option<Evaluator> {
    let dir = artifacts()?;
    let rt = PjrtRuntime::cpu().expect("pjrt client");
    let reg = ArtifactRegistry::load(&rt, &dir, variant).expect("registry");
    Some(Evaluator::new(rt, reg).expect("evaluator"))
}

#[test]
fn qgemm_artifact_matches_cpu_reference() {
    let Some(dir) = artifacts() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let exe = rt.load_hlo_text(&dir.join("qgemm_pallas.hlo.txt")).unwrap();
    // artifact shape: a[256,288] b[288,32] bits scalars mask[32]
    let (m, k, n) = (256usize, 288usize, 32usize);
    let mut rng = galen::util::rng::Pcg64::new(3);
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
    let mask: Vec<f32> = (0..n).map(|i| (i % 3 != 0) as u8 as f32).collect();
    let out = exe
        .run(
            &rt,
            &[
                HostTensor::new(vec![m, k], a.clone()),
                HostTensor::new(vec![k, n], b.clone()),
                HostTensor::scalar(0.0), // a_bits: bypass
                HostTensor::scalar(0.0), // w_bits: bypass
                HostTensor::new(vec![n], mask.clone()),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].shape, vec![m, n]);
    // FP32 bypass: must equal a plain masked GEMM
    for i in 0..8 {
        for j in 0..n {
            let mut s = 0.0f64;
            for kk in 0..k {
                s += a[i * k + kk] as f64 * b[kk * n + j] as f64;
            }
            let expect = s as f32 * mask[j];
            let got = out[0].data[i * n + j];
            assert!(
                (got - expect).abs() <= 1e-3 * (1.0 + expect.abs()),
                "[{i},{j}] {got} vs {expect}"
            );
        }
    }
}

#[test]
fn qgemm_artifact_quantized_masks_and_compresses() {
    let Some(dir) = artifacts() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let exe = rt.load_hlo_text(&dir.join("qgemm_pallas.hlo.txt")).unwrap();
    let (m, k, n) = (256usize, 288usize, 32usize);
    let mut rng = galen::util::rng::Pcg64::new(4);
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
    let mut mask = vec![1.0f32; n];
    mask[0] = 0.0;
    mask[17] = 0.0;
    let run = |a_bits: f32, w_bits: f32| {
        exe.run(
            &rt,
            &[
                HostTensor::new(vec![m, k], a.clone()),
                HostTensor::new(vec![k, n], b.clone()),
                HostTensor::scalar(a_bits),
                HostTensor::scalar(w_bits),
                HostTensor::new(vec![n], mask.clone()),
            ],
        )
        .unwrap()
        .remove(0)
    };
    let exact = run(0.0, 0.0);
    let q8 = run(8.0, 8.0);
    let q2 = run(2.0, 2.0);
    // masked columns are exactly zero in all modes
    for out in [&exact, &q8, &q2] {
        for i in 0..m {
            assert_eq!(out.data[i * n], 0.0);
            assert_eq!(out.data[i * n + 17], 0.0);
        }
    }
    // more bits => closer to exact
    let err = |o: &HostTensor| -> f64 {
        o.data
            .iter()
            .zip(&exact.data)
            .map(|(x, y)| ((x - y).abs()) as f64)
            .sum::<f64>()
            / o.data.len() as f64
    };
    assert!(err(&q8) < err(&q2), "8-bit {} vs 2-bit {}", err(&q8), err(&q2));
}

#[test]
fn micro_forward_reference_policy_accuracy() {
    let Some(ev) = evaluator("micro") else { return };
    let p = DiscretePolicy::reference(&ev.reg.ir);
    let acc = ev.accuracy(&p, Split::Test, 4).unwrap();
    // aot.py reported ~0.999 test accuracy for the trained micro model
    assert!(acc > 0.95, "uncompressed accuracy {acc}");
    let val = ev.accuracy(&p, Split::Val, 4).unwrap();
    assert!(val > 0.95, "val accuracy {val}");
}

#[test]
fn micro_forward_int8_keeps_accuracy_one_bit_destroys() {
    let Some(ev) = evaluator("micro") else { return };
    let ir = &ev.reg.ir;
    let mut int8 = DiscretePolicy::reference(ir);
    for l in &mut int8.layers {
        l.quant = QuantMode::Int8;
    }
    let acc8 = ev.accuracy(&int8, Split::Val, 4).unwrap();
    assert!(acc8 > 0.9, "INT8 accuracy collapsed: {acc8}");

    let mut one_bit = DiscretePolicy::reference(ir);
    for l in &mut one_bit.layers {
        l.quant = QuantMode::Mix {
            w_bits: 1,
            a_bits: 1,
        };
    }
    let acc1 = ev.accuracy(&one_bit, Split::Val, 4).unwrap();
    assert!(
        acc1 < acc8 - 0.2,
        "1-bit ({acc1}) should be far below INT8 ({acc8})"
    );
}

#[test]
fn micro_forward_pruning_mask_degrades_gracefully() {
    let Some(ev) = evaluator("micro") else { return };
    let ir = &ev.reg.ir;
    let base = ev
        .accuracy(&DiscretePolicy::reference(ir), Split::Val, 2)
        .unwrap();
    // prune half the channels of every prunable layer
    let mut pruned = DiscretePolicy::reference(ir);
    for &i in &ir.prunable_layers() {
        pruned.layers[i].kept_channels = (ir.layers[i].cout / 2).max(1);
    }
    let acc = ev.accuracy(&pruned, Split::Val, 2).unwrap();
    assert!(acc <= base + 1e-9);
    assert!(acc > 0.3, "half-pruning should not destroy the model: {acc}");
}

#[test]
fn sensitivity_probes_increase_with_compression_strength() {
    let Some(ev) = evaluator("micro") else { return };
    use galen::eval::{SensitivityConfig, SensitivityTable};
    let cfg = SensitivityConfig {
        prune_ratios: vec![0.5],
        w_bits: vec![1, 8],
        a_bits: vec![8],
        batches: 1,
    };
    let t = SensitivityTable::compute(&ev, &cfg).unwrap();
    assert_eq!(t.prune.len(), ev.reg.ir.layers.len());
    // 1-bit weight quantization must distort more than 8-bit on most layers
    let mut more = 0;
    for l in &t.quant_w {
        if l[0].omega > l[1].omega {
            more += 1;
        }
    }
    assert!(
        more * 2 >= t.quant_w.len(),
        "1-bit omega should dominate 8-bit on most layers ({more}/{})",
        t.quant_w.len()
    );
}

#[test]
fn pallas_forward_artifact_matches_xla_forward() {
    let Some(dir) = artifacts() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let xla_reg = ArtifactRegistry::load(&rt, &dir, "micro").unwrap();
    let pal_reg = ArtifactRegistry::load_with(&rt, &dir, "micro", true).unwrap();

    // identical inputs: first 16 val images (pallas artifact batch = 16)
    let img: usize = 32 * 32 * 3;
    let x = HostTensor::new(
        vec![16, 32, 32, 3],
        xla_reg.dataset.val_x.data[..16 * img].to_vec(),
    );
    let policy = DiscretePolicy::reference(&xla_reg.ir);
    let inputs = galen::compress::PolicyInputs::build(
        &xla_reg.ir,
        &policy,
        &xla_reg.params_by_name,
    )
    .unwrap();
    let mut args: Vec<HostTensor> = vec![x];
    args.extend(xla_reg.params.iter().cloned());
    for (buf, e) in inputs.buffers.iter().zip(&xla_reg.meta.policy) {
        args.push(HostTensor::new(e.shape.clone(), buf.clone()));
    }
    let pal_out = pal_reg.fwd.run(&rt, &args).unwrap().remove(0);
    assert_eq!(pal_out.shape, vec![16, 10]);

    // XLA fwd artifact has batch 128; evaluate the same 16 rows via the
    // evaluator probs on batch 0 and compare argmax agreement.
    let ev = Evaluator::new(rt, xla_reg).unwrap();
    let p = ev.probs(&policy, 0).unwrap();
    let classes = 10;
    let mut agree = 0;
    for i in 0..16 {
        let pal_pred = (0..classes)
            .max_by(|&a, &b| {
                pal_out.data[i * classes + a]
                    .partial_cmp(&pal_out.data[i * classes + b])
                    .unwrap()
            })
            .unwrap();
        let xla_pred = (0..classes)
            .max_by(|&a, &b| {
                p[i * classes + a].partial_cmp(&p[i * classes + b]).unwrap()
            })
            .unwrap();
        agree += (pal_pred == xla_pred) as usize;
    }
    assert!(agree >= 15, "pallas/XLA prediction agreement {agree}/16");
}

#[test]
fn train_step_artifact_reduces_loss() {
    let Some(ev) = evaluator("micro") else { return };
    use galen::eval::{retrain, RetrainCfg};
    let ir = &ev.reg.ir;
    // compress hard enough that there is something to recover
    let mut policy = DiscretePolicy::reference(ir);
    for l in &mut policy.layers {
        l.quant = QuantMode::Mix {
            w_bits: 3,
            a_bits: 4,
        };
    }
    let report = retrain(
        &ev,
        &policy,
        &RetrainCfg {
            steps: 12,
            lr: 2e-3,
            seed: 5,
        },
    )
    .unwrap();
    assert_eq!(report.losses.len(), 12);
    let first2 = (report.losses[0] + report.losses[1]) / 2.0;
    let last2 = (report.losses[10] + report.losses[11]) / 2.0;
    assert!(
        last2 <= first2 * 1.05,
        "retraining diverged: first {first2} last {last2}"
    );
    assert_eq!(report.params.len(), ev.reg.params.len());
}
