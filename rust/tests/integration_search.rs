//! End-to-end search integration: real PJRT accuracy + simulated hardware
//! latency on the micro variant (skipped when artifacts are absent), and
//! artifact-free zoo searches on the depthwise mobilenetv2s workload with
//! every agent under both the sim and measured latency backends.

use std::path::PathBuf;

use galen::agent::{mapper_for, AgentKind, DdpgConfig};
use galen::compress::DiscretePolicy;
use galen::eval::{SensitivityConfig, SensitivityTable};
use galen::coordinator::{Backend, Session, SessionOptions};
use galen::hw::{CostModel, HwTarget, LatencySimulator, MeasuredProfiler, ProfilerConfig};
use galen::model::ModelIr;
use galen::search::{run_search, SearchConfig, SimEvaluator};

fn opts(backend: Backend) -> Option<SessionOptions> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("meta_micro.json").exists() {
        eprintln!("SKIP: artifacts/ not built");
        return None;
    }
    let mut o = SessionOptions::new("micro");
    o.artifacts_dir = dir;
    o.backend = backend;
    // light sensitivity grid keeps the test fast; cached across tests
    o.sensitivity = SensitivityConfig {
        prune_ratios: vec![0.5],
        w_bits: vec![2, 8],
        a_bits: vec![2, 8],
        batches: 1,
    };
    o.sensitivity_cache =
        Some(std::env::temp_dir().join(format!("galen_test_sens_{}.json", std::process::id())));
    Some(o)
}

fn small_cfg(agent: AgentKind, target: f64) -> SearchConfig {
    let mut cfg = SearchConfig::fast(agent, target);
    cfg.episodes = 14;
    cfg.warmup_episodes = 6;
    cfg.eval_batches = 1;
    cfg.opt_steps_per_episode = 5;
    cfg.log_every = 0;
    cfg.ddpg = DdpgConfig {
        hidden: (64, 48),
        batch: 32,
        replay_capacity: 500,
        ..Default::default()
    };
    cfg
}

fn mobilenet_fixture() -> (ModelIr, SensitivityTable) {
    let ir = ModelIr::from_meta(&galen::model::zoo::meta("mobilenetv2s").unwrap()).unwrap();
    let sens = SensitivityTable::disabled(
        ir.layers.len(),
        &SensitivityConfig::default(),
        "mobilenetv2s",
    );
    (ir, sens)
}

fn tiny_cfg(agent: AgentKind, target: f64) -> SearchConfig {
    let mut cfg = SearchConfig::fast(agent, target);
    cfg.episodes = 8;
    cfg.warmup_episodes = 3;
    cfg.opt_steps_per_episode = 4;
    cfg.eval_batches = 1;
    cfg.log_every = 0;
    cfg.ddpg = DdpgConfig {
        hidden: (32, 24),
        batch: 24,
        replay_capacity: 400,
        ..Default::default()
    };
    cfg
}

/// Depthwise invariants every searched mobilenetv2s policy must satisfy:
/// depthwise widths follow their expand producer, and no depthwise layer
/// ever carries the bit-serial MIX mode.
fn assert_depthwise_invariants(ir: &ModelIr, policy: &DiscretePolicy) {
    for l in ir.layers.iter().filter(|l| l.depthwise) {
        assert!(!policy.layers[l.index].quant.is_mix(), "{} went MIX", l.name);
        let producer = ir.producer_of(l.index).expect("depthwise conv has a producer");
        assert_eq!(
            policy.layers[l.index].kept_channels, policy.layers[producer].kept_channels,
            "{} decoupled from {}",
            l.name,
            ir.layers[producer].name
        );
    }
}

/// Acceptance: the mobilenetv2s workload searches end to end with all three
/// agents on the simulator backend, with depthwise layers carrying
/// non-trivial costs (depthwise MACs != dense MACs) and the coupling
/// constraints respected by every best policy.
#[test]
fn mobilenetv2s_sim_search_all_agents() {
    let (ir, sens) = mobilenet_fixture();
    for agent in [AgentKind::Pruning, AgentKind::Quantization, AgentKind::Joint] {
        let ev = SimEvaluator::new(&ir);
        let mapper = mapper_for(agent);
        let mut sim = LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), 11);
        let cfg = tiny_cfg(agent, 0.5);
        let out = run_search(&ir, &sens, &ev, &mut sim, mapper.as_ref(), &cfg, None)
            .unwrap_or_else(|e| panic!("{agent}: {e:#}"));
        assert_eq!(out.history.len(), 8, "{agent}");
        assert!(out.best.latency_s > 0.0 && out.base_latency_s > 0.0, "{agent}");
        assert_depthwise_invariants(&ir, &out.best_policy);
        // depthwise MACs are not dense MACs: the policy's MAC accounting
        // must stay below what dense accounting of the same shapes gives
        let dense_equiv: u64 = ir
            .layers
            .iter()
            .map(|l| {
                let cin = out.best_policy.effective_cin(&ir, l.index);
                let kept = out.best_policy.layers[l.index].kept_channels;
                match l.kind {
                    galen::model::LayerKind::Conv => {
                        (l.kernel * l.kernel) as u64
                            * cin as u64
                            * kept as u64
                            * (l.out_spatial * l.out_spatial) as u64
                    }
                    galen::model::LayerKind::Linear => (cin * kept) as u64,
                }
            })
            .sum();
        assert!(out.best.macs < dense_equiv, "{agent}: depthwise accounting inert");
    }
}

/// Acceptance: the same workload searches under the measured-kernel
/// profiler backend — depthwise configs lower to the real windowed kernels
/// and get timed.
#[test]
fn mobilenetv2s_measured_search_runs() {
    let (ir, sens) = mobilenet_fixture();
    let ev = SimEvaluator::new(&ir);
    let mapper = mapper_for(AgentKind::Joint);
    let mut profiler = MeasuredProfiler::new(
        HwTarget::cortex_a72(),
        "mobilenetv2s",
        ProfilerConfig::fast(),
    );
    let mut cfg = tiny_cfg(AgentKind::Joint, 0.5);
    cfg.episodes = 5;
    cfg.warmup_episodes = 2;
    let out = run_search(&ir, &sens, &ev, &mut profiler, mapper.as_ref(), &cfg, None).unwrap();
    assert_eq!(out.latency_backend, "measured");
    assert_eq!(out.history.len(), 5);
    assert!(out.best.latency_s > 0.0);
    assert!(profiler.stats().measured > 0, "nothing was actually timed");
    assert_depthwise_invariants(&ir, &out.best_policy);
}

#[test]
fn pjrt_backed_joint_search_end_to_end() {
    let Some(o) = opts(Backend::Pjrt) else { return };
    let session = Session::open(o).expect("session");
    let out = session
        .search(&small_cfg(AgentKind::Joint, 0.4))
        .expect("search");
    assert_eq!(out.history.len(), 14);
    // every episode produced a real accuracy in [0,1] and positive latency
    for h in &out.history {
        assert!((0.0..=1.0).contains(&h.accuracy));
        assert!(h.latency_s > 0.0);
        assert!(h.macs <= session.ir.total_macs());
    }
    // search must find something compressing below the fp32 reference
    assert!(out.relative_latency() < 1.0);
    // best policy accuracy is evaluated on the real model: better than chance
    assert!(out.best.accuracy > 0.2);
}

#[test]
fn pjrt_sequential_scheme_runs() {
    let Some(o) = opts(Backend::Pjrt) else { return };
    let session = Session::open(o).expect("session");
    let (s1, s2) = session
        .sequential(
            AgentKind::Pruning,
            0.4,
            &small_cfg(AgentKind::Pruning, 0.4),
        )
        .expect("sequential");
    // stage-2 policy preserves stage-1 pruning
    for l in &session.ir.layers {
        assert_eq!(
            s2.best_policy.layers[l.index].kept_channels,
            s1.best_policy.layers[l.index].kept_channels
        );
    }
}
