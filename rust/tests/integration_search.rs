//! End-to-end search integration: real PJRT accuracy + simulated hardware
//! latency, on the micro variant (fast).  Skipped when artifacts are absent.

use std::path::PathBuf;

use galen::agent::{AgentKind, DdpgConfig};
use galen::coordinator::{Backend, Session, SessionOptions};
use galen::eval::SensitivityConfig;
use galen::search::SearchConfig;

fn opts(backend: Backend) -> Option<SessionOptions> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("meta_micro.json").exists() {
        eprintln!("SKIP: artifacts/ not built");
        return None;
    }
    let mut o = SessionOptions::new("micro");
    o.artifacts_dir = dir;
    o.backend = backend;
    // light sensitivity grid keeps the test fast; cached across tests
    o.sensitivity = SensitivityConfig {
        prune_ratios: vec![0.5],
        w_bits: vec![2, 8],
        a_bits: vec![2, 8],
        batches: 1,
    };
    o.sensitivity_cache =
        Some(std::env::temp_dir().join(format!("galen_test_sens_{}.json", std::process::id())));
    Some(o)
}

fn small_cfg(agent: AgentKind, target: f64) -> SearchConfig {
    let mut cfg = SearchConfig::fast(agent, target);
    cfg.episodes = 14;
    cfg.warmup_episodes = 6;
    cfg.eval_batches = 1;
    cfg.opt_steps_per_episode = 5;
    cfg.log_every = 0;
    cfg.ddpg = DdpgConfig {
        hidden: (64, 48),
        batch: 32,
        replay_capacity: 500,
        ..Default::default()
    };
    cfg
}

#[test]
fn pjrt_backed_joint_search_end_to_end() {
    let Some(o) = opts(Backend::Pjrt) else { return };
    let session = Session::open(o).expect("session");
    let out = session
        .search(&small_cfg(AgentKind::Joint, 0.4))
        .expect("search");
    assert_eq!(out.history.len(), 14);
    // every episode produced a real accuracy in [0,1] and positive latency
    for h in &out.history {
        assert!((0.0..=1.0).contains(&h.accuracy));
        assert!(h.latency_s > 0.0);
        assert!(h.macs <= session.ir.total_macs());
    }
    // search must find something compressing below the fp32 reference
    assert!(out.relative_latency() < 1.0);
    // best policy accuracy is evaluated on the real model: better than chance
    assert!(out.best.accuracy > 0.2);
}

#[test]
fn pjrt_sequential_scheme_runs() {
    let Some(o) = opts(Backend::Pjrt) else { return };
    let session = Session::open(o).expect("session");
    let (s1, s2) = session
        .sequential(
            AgentKind::Pruning,
            0.4,
            &small_cfg(AgentKind::Pruning, 0.4),
        )
        .expect("sequential");
    // stage-2 policy preserves stage-1 pruning
    for l in &session.ir.layers {
        assert_eq!(
            s2.best_policy.layers[l.index].kept_channels,
            s1.best_policy.layers[l.index].kept_channels
        );
    }
}
