//! Networked serve: handshake gating, admission control, multi-client job
//! isolation, and durability under concurrent connections.
//!
//! The acceptance scenarios for `galen serve --listen`: N concurrent
//! clients never see each other's jobs without the job token, a submit
//! racing a drain can never journal a never-accepted job, and a serve
//! process hard-killed mid-session over TCP resumes with `--resume-jobs`
//! to a bit-identical artifact.

mod common;

use std::io::{BufRead, BufReader, Cursor};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::{Barrier, Mutex};
use std::time::Duration;

use common::{factory, fixture, hello_line, submit_line, with_server, Client};
use galen::coordinator::{
    replay_journal, serve, NetOptions, ServeOptions, SERVE_PROTOCOL_VERSION,
};
use galen::util::json::Json;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("galen_net_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// A deliberately long job (many episodes) — keeps a single worker busy
/// while the test lines up queue-cap scenarios behind it.
fn slow_submit_line(id: &str) -> String {
    format!(
        r#"{{"op":"submit","id":"{id}","spec":{{"agent":"quantization","target":0.5,"preset":"fast","config":{{"episodes":60,"warmup_episodes":3,"opt_steps_per_episode":4,"log_every":0,"ddpg":{{"hidden":[24,16],"batch":16,"replay_capacity":200}}}}}}}}"#
    )
}

/// A deliberately tiny job — lets the drain-race test accept many jobs and
/// still finish them all while the service drains.
fn quick_submit_line(id: &str) -> String {
    format!(
        r#"{{"op":"submit","id":"{id}","spec":{{"agent":"quantization","target":0.5,"preset":"fast","config":{{"episodes":2,"warmup_episodes":1,"opt_steps_per_episode":1,"log_every":0,"ddpg":{{"hidden":[16,12],"batch":8,"replay_capacity":64}}}}}}}}"#
    )
}

/// Socket connections must open with a successful `hello`: every op before
/// one is refused, a version mismatch echoes both versions and leaves the
/// connection open for a retry, and a later correct hello unlocks the
/// session.
#[test]
fn socket_ops_are_gated_on_the_versioned_handshake() {
    let opts = ServeOptions { workers: 1, ..Default::default() };
    with_server("127.0.0.1:0", &opts, &NetOptions::default(), |addr| {
        let mut client = Client::connect_tcp(addr);

        let r = client.roundtrip(r#"{"op":"list","id":"early"}"#);
        assert!(!r.req_bool("ok").unwrap());
        assert!(r.req_str("error").unwrap().contains("handshake required"), "{}", r.dump());
        assert_eq!(r.req_str("id").unwrap(), "early");

        let r = client.roundtrip(r#"{"op":"hello","id":"old","protocol":1}"#);
        assert!(!r.req_bool("ok").unwrap());
        assert!(r.req_str("error").unwrap().contains("protocol version mismatch"));
        assert_eq!(r.get("client_protocol").and_then(Json::as_usize), Some(1));
        assert_eq!(
            r.get("server_protocol").and_then(Json::as_usize),
            Some(SERVE_PROTOCOL_VERSION)
        );

        // the mismatch did not unlock anything
        let r = client.roundtrip(r#"{"op":"list","id":"still"}"#);
        assert!(!r.req_bool("ok").unwrap());
        assert!(r.req_str("error").unwrap().contains("handshake required"));

        // ... but the connection stayed open: a correct retry succeeds
        let r = client.roundtrip(&hello_line("retry"));
        assert!(r.req_bool("ok").unwrap(), "{}", r.dump());
        let r = client.roundtrip(r#"{"op":"list","id":"after"}"#);
        assert!(r.req_bool("ok").unwrap(), "{}", r.dump());

        client.send(r#"{"op":"shutdown"}"#);
    });
}

/// Above the connection cap, a client gets exactly one structured
/// rejection line carrying `retry_after_ms`, then the socket closes — and
/// the admitted client is entirely unaffected.
#[test]
fn connections_above_the_cap_get_one_rejection_line() {
    let opts = ServeOptions { workers: 1, ..Default::default() };
    let net = NetOptions { max_connections: 1 };
    with_server("127.0.0.1:0", &opts, &net, |addr| {
        let mut admitted = Client::connect_tcp(addr);
        // a served response proves this connection's thread is live (and
        // counted) before the second connection races the cap check
        admitted.hello();

        let mut rejected = Client::connect_tcp(addr);
        let r = rejected.recv();
        assert!(!r.req_bool("ok").unwrap());
        assert!(r.req_str("error").unwrap().contains("connection capacity"), "{}", r.dump());
        assert_eq!(r.get("retry_after_ms").and_then(Json::as_usize), Some(500));
        assert!(rejected.recv_or_dead().is_none(), "rejected socket must close");

        let r = admitted.roundtrip(r#"{"op":"list","id":"fine"}"#);
        assert!(r.req_bool("ok").unwrap(), "{}", r.dump());
        admitted.send(r#"{"op":"shutdown"}"#);
    });
}

/// Once `max_queued_jobs` submissions are waiting for a worker, further
/// submits are refused with a structured `ok:false` + the configured
/// `retry_after_ms` — the connection and the running work are untouched.
#[test]
fn submits_above_the_queue_cap_are_rejected_with_retry_hint() {
    let opts = ServeOptions {
        workers: 1,
        max_queued_jobs: 1,
        retry_after_ms: 123,
        ..Default::default()
    };
    let (stats, ()) = with_server("127.0.0.1:0", &opts, &NetOptions::default(), |addr| {
        let mut client = Client::connect_tcp(addr);
        client.hello();

        let r = client.roundtrip(&slow_submit_line("a"));
        assert!(r.req_bool("ok").unwrap(), "{}", r.dump());
        // wait until the worker picked job-0 up: only then is the queue
        // provably empty, making the next two submits deterministic
        loop {
            let r = client.roundtrip(r#"{"op":"status","id":"p","job":"job-0"}"#);
            if r.req_str("state").unwrap() == "running" {
                break;
            }
            std::thread::sleep(Duration::from_millis(20));
        }

        let r = client.roundtrip(&slow_submit_line("b"));
        assert!(r.req_bool("ok").unwrap(), "one queued job is within the cap: {}", r.dump());

        let r = client.roundtrip(&slow_submit_line("c"));
        assert!(!r.req_bool("ok").unwrap(), "the cap must refuse the second: {}", r.dump());
        assert!(r.req_str("error").unwrap().contains("queue is full"), "{}", r.dump());
        assert_eq!(r.get("retry_after_ms").and_then(Json::as_usize), Some(123));
        assert_eq!(r.req_str("id").unwrap(), "c");

        // unwind: cancel both accepted jobs and wait them terminal
        for job in ["job-1", "job-0"] {
            let r = client.roundtrip(&format!(r#"{{"op":"cancel","id":"cx","job":"{job}"}}"#));
            assert!(r.req_bool("ok").unwrap(), "{}", r.dump());
        }
        for job in ["job-0", "job-1"] {
            let r = client
                .roundtrip(&format!(r#"{{"op":"result","id":"rw","job":"{job}","wait":true}}"#));
            assert_eq!(r.req_str("state").unwrap(), "cancelled", "{}", r.dump());
        }
        client.send(r#"{"op":"shutdown"}"#);
    });
    assert_eq!(stats.submitted, 2, "the rejected submit must not count as accepted");
    assert_eq!(stats.cancelled, 2);
}

/// The multi-client acceptance scenario: N concurrent clients submit,
/// poll and cancel interleaved jobs.  No client can see or touch another
/// client's job without its token; with the token, everything works; a
/// late connection's `list` shows none of them.
#[test]
fn concurrent_clients_cannot_touch_each_others_jobs_without_the_token() {
    const N: usize = 4;
    let opts = ServeOptions { workers: 2, ..Default::default() };
    let (stats, ()) = with_server("127.0.0.1:0", &opts, &NetOptions::default(), |addr| {
        let published: Mutex<Vec<Option<(String, String)>>> = Mutex::new(vec![None; N]);
        let barrier = Barrier::new(N);
        std::thread::scope(|scope| {
            for i in 0..N {
                let published = &published;
                let barrier = &barrier;
                scope.spawn(move || {
                    let mut client = Client::connect_tcp(addr);
                    client.hello();
                    let r = client.roundtrip(&submit_line(
                        &format!("t{i}"),
                        "quantization",
                        0.3 + 0.1 * i as f64,
                    ));
                    assert!(r.req_bool("ok").unwrap(), "client {i}: {}", r.dump());
                    let job = r.req_str("job").unwrap().to_string();
                    let token = r.req_str("token").unwrap().to_string();
                    published.lock().unwrap()[i] = Some((job.clone(), token));
                    barrier.wait();

                    // the neighbour's job: invisible without its token ...
                    let (their_job, their_token) =
                        published.lock().unwrap()[(i + 1) % N].clone().unwrap();
                    let r = client.roundtrip(&format!(
                        r#"{{"op":"status","id":"spy","job":"{their_job}"}}"#
                    ));
                    assert!(
                        !r.req_bool("ok").unwrap(),
                        "client {i} saw a foreign job: {}",
                        r.dump()
                    );
                    assert!(
                        r.req_str("error").unwrap().contains("belongs to another connection"),
                        "{}",
                        r.dump()
                    );
                    // ... fully accessible with it
                    let r = client.roundtrip(&format!(
                        r#"{{"op":"status","id":"tok","job":"{their_job}","token":"{their_token}"}}"#
                    ));
                    assert!(r.req_bool("ok").unwrap(), "client {i}: token refused: {}", r.dump());

                    // list enumerates exactly this client's own work
                    let r = client.roundtrip(r#"{"op":"list","id":"mine"}"#);
                    let jobs = r.req_arr("jobs").unwrap();
                    assert_eq!(jobs.len(), 1, "client {i}: {}", r.dump());
                    assert_eq!(jobs[0].req_str("job").unwrap(), job);

                    // odd clients cancel mid-flight; even ones run to the end
                    if i % 2 == 1 {
                        let r = client
                            .roundtrip(&format!(r#"{{"op":"cancel","id":"c","job":"{job}"}}"#));
                        assert!(r.req_bool("ok").unwrap(), "{}", r.dump());
                    }
                    let r = client.roundtrip(&format!(
                        r#"{{"op":"result","id":"r","job":"{job}","wait":true}}"#
                    ));
                    let state = r.req_str("state").unwrap();
                    assert!(
                        state == "done" || state == "cancelled",
                        "client {i}: job ended {state}: {}",
                        r.dump()
                    );
                });
            }
        });
        let mut late = Client::connect_tcp(addr);
        late.hello();
        let r = late.roundtrip(r#"{"op":"list","id":"late"}"#);
        assert_eq!(
            r.req_arr("jobs").unwrap().len(),
            0,
            "a fresh connection must see no foreign jobs: {}",
            r.dump()
        );
        late.send(r#"{"op":"shutdown"}"#);
    });
    assert_eq!(stats.submitted, N);
    assert_eq!(stats.failed, 0);
    assert_eq!(stats.completed + stats.cancelled, N, "{stats:?}");
}

/// The drain-race regression: submits hammering the service while another
/// connection triggers shutdown.  Every journaled job must be one the
/// service actually accepted *and* ran to a terminal state — a submit
/// racing the drain can neither journal a never-accepted job nor leave an
/// accepted one stranded.  A follow-up plain session over the same journal
/// dir starts clean.
#[test]
fn submit_racing_drain_never_journals_a_never_accepted_job() {
    const SUBMITTERS: usize = 2;
    let dir = tmp_dir("drainrace");
    let opts = ServeOptions {
        workers: 2,
        journal_dir: Some(dir.clone()),
        ..Default::default()
    };
    let (stats, accepted) = with_server("127.0.0.1:0", &opts, &NetOptions::default(), |addr| {
        let accepted: Mutex<usize> = Mutex::new(0);
        std::thread::scope(|scope| {
            for t in 0..SUBMITTERS {
                let accepted = &accepted;
                scope.spawn(move || {
                    let mut client = Client::connect_tcp(addr);
                    client.hello();
                    for case in 0..20 {
                        if client.try_send(&quick_submit_line(&format!("s{t}-{case}"))).is_err() {
                            break;
                        }
                        match client.recv_or_dead() {
                            None => break, // drained: the connection closed
                            Some(line) => {
                                let r = Json::parse(&line).unwrap();
                                if r.req_bool("ok").unwrap() {
                                    *accepted.lock().unwrap() += 1;
                                } else {
                                    // the drain beat this submit to the locks
                                    assert!(
                                        r.req_str("error").unwrap().contains("shutting down"),
                                        "{line}"
                                    );
                                    break;
                                }
                            }
                        }
                        std::thread::sleep(Duration::from_millis(25));
                    }
                });
            }
            // let some submits land, then pull the plug mid-hammering
            std::thread::sleep(Duration::from_millis(250));
            let mut killer = Client::connect_tcp(addr);
            killer.hello();
            let r = killer.roundtrip(r#"{"op":"shutdown","id":"kill"}"#);
            assert!(r.req_bool("ok").unwrap(), "{}", r.dump());
        });
        let accepted = *accepted.lock().unwrap();
        assert!(accepted > 0, "the race needs at least one accepted submit");
        accepted
    });

    // acceptance == journal == terminal: nothing phantom, nothing stranded
    assert_eq!(stats.submitted, accepted, "every ok:true submit is an accepted job");
    assert_eq!(stats.completed + stats.cancelled, stats.submitted, "{stats:?}");
    assert_eq!(stats.failed, 0, "{stats:?}");
    let replayed = replay_journal(&dir).unwrap();
    assert_eq!(
        replayed.len(),
        accepted,
        "the journal must record exactly the accepted jobs"
    );
    for job in &replayed {
        assert!(
            job.status.is_terminal(),
            "journaled job {} left non-terminal: {:?}",
            job.id,
            job.status
        );
    }

    // the journal is all-terminal, so a plain (non-resume) session over the
    // same dir must start clean instead of refusing
    let (ir, sens) = fixture();
    let factory = factory();
    let mut out = Vec::new();
    let stats = serve(
        &ir,
        &sens,
        &factory,
        "tiny",
        &ServeOptions {
            workers: 1,
            journal_dir: Some(dir.clone()),
            ..Default::default()
        },
        Cursor::new(r#"{"op":"list","id":"clean"}"#.to_string()),
        &mut out,
    )
    .expect("a cleanly-drained journal must not block the next session");
    assert_eq!(stats.submitted + stats.resumed, 0);
    let r = Json::parse(String::from_utf8(out).unwrap().lines().next().unwrap()).unwrap();
    assert_eq!(r.req_arr("jobs").unwrap().len(), 0);

    std::fs::remove_dir_all(&dir).ok();
}

/// Spawn the real binary with `--listen 127.0.0.1:0` and return the child
/// plus the address it announced on stdout.
fn spawn_serve_bin(dir: &Path, extra: &[&str], faults: Option<&str>) -> (Child, String) {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_galen"));
    cmd.arg("serve")
        .args(["--fixture", "--jobs", "1", "--seed", "7", "--checkpoint-every", "2"])
        .arg("--results")
        .arg(dir)
        .args(["--listen", "127.0.0.1:0"])
        .args(extra)
        .env_remove("GALEN_FAULTS")
        .stdin(Stdio::null())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped());
    if let Some(f) = faults {
        cmd.env("GALEN_FAULTS", f);
    }
    let mut child = cmd.spawn().unwrap();
    let mut line = String::new();
    BufReader::new(child.stdout.as_mut().unwrap())
        .read_line(&mut line)
        .unwrap();
    let addr = line
        .trim()
        .strip_prefix("listening on ")
        .unwrap_or_else(|| panic!("unexpected announcement: {line:?}"))
        .to_string();
    (child, addr)
}

/// The durability acceptance scenario over TCP against the real binary:
/// hard-kill a networked serve mid-session (injected abort), verify the
/// journal recorded the interruption, verify a plain restart refuses it,
/// then `--resume-jobs` over TCP again — the finished artifact is
/// bit-identical to an uninterrupted networked run.
#[test]
fn killed_tcp_serve_resumes_bit_identically() {
    // reference: an uninterrupted networked session
    let ref_dir = tmp_dir("bin_ref");
    let (child, addr) = spawn_serve_bin(&ref_dir, &[], None);
    {
        let mut client = Client::connect_tcp(&addr);
        client.hello();
        let r = client.roundtrip(&submit_line("a", "joint", 0.4));
        assert!(r.req_bool("ok").unwrap(), "{}", r.dump());
        let r = client.roundtrip(r#"{"op":"result","id":"r","job":"job-0","wait":true}"#);
        assert_eq!(r.req_str("state").unwrap(), "done", "{}", r.dump());
        client.send(r#"{"op":"shutdown"}"#);
    }
    let out = child.wait_with_output().unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let reference = std::fs::read(ref_dir.join("serve_tiny_job-0.json")).unwrap();

    // crash: the 4th episode aborts the process under a live TCP client
    let dir = tmp_dir("bin_crash");
    let (child, addr) = spawn_serve_bin(&dir, &[], Some("episode:4:abort"));
    {
        let mut client = Client::connect_tcp(&addr);
        client.hello();
        let r = client.roundtrip(&submit_line("a", "joint", 0.4));
        assert!(r.req_bool("ok").unwrap(), "{}", r.dump());
        client.send(r#"{"op":"result","id":"r","job":"job-0","wait":true}"#);
        assert!(
            client.recv_or_dead().is_none(),
            "the injected abort must sever the connection"
        );
    }
    let out = child.wait_with_output().unwrap();
    assert!(!out.status.success(), "the abort must kill the process");
    assert!(!dir.join("serve_tiny_job-0.json").exists());
    let replayed = replay_journal(&dir).unwrap();
    assert_eq!(replayed.len(), 1);
    assert!(!replayed[0].status.is_terminal(), "journal records the interruption");

    // a plain restart must refuse the interrupted journal, --listen or not
    let out = Command::new(env!("CARGO_BIN_EXE_galen"))
        .arg("serve")
        .args(["--fixture", "--jobs", "1", "--seed", "7", "--checkpoint-every", "2"])
        .arg("--results")
        .arg(&dir)
        .args(["--listen", "127.0.0.1:0"])
        .env_remove("GALEN_FAULTS")
        .stdin(Stdio::null())
        .output()
        .unwrap();
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("--resume-jobs"), "stderr: {stderr}");

    // --resume-jobs finishes the job; replayed jobs are ownerless, so the
    // new connection reads the result without any token
    let (child, addr) = spawn_serve_bin(&dir, &["--resume-jobs"], None);
    {
        let mut client = Client::connect_tcp(&addr);
        client.hello();
        let r = client.roundtrip(r#"{"op":"result","id":"r","job":"job-0","wait":true}"#);
        assert_eq!(r.req_str("state").unwrap(), "done", "{}", r.dump());
        client.send(r#"{"op":"shutdown"}"#);
    }
    let out = child.wait_with_output().unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let resumed = std::fs::read(dir.join("serve_tiny_job-0.json")).unwrap();
    assert_eq!(resumed, reference, "resumed artifact must be bit-identical");

    std::fs::remove_dir_all(&ref_dir).ok();
    std::fs::remove_dir_all(&dir).ok();
}
