//! Chaos tests for the fault-tolerant serve layer: panic isolation,
//! journal-driven crash recovery (in-process and against the real binary),
//! checkpoint self-healing, and corrupt-artifact hardening.
//!
//! The recovery tests all assert the acceptance criterion of the failure
//! model: an interrupted session restarted with `--resume-jobs` produces
//! *bit-identical* job results to the uninterrupted run.

use std::io::Cursor;
use std::path::{Path, PathBuf};

use galen::agent::AgentKind;
use galen::coordinator::{serve, JobStatus, ServeJournal, ServeOptions, ServeStats};
use galen::eval::{SensitivityConfig, SensitivityTable};
use galen::hw::{HwTarget, LatencyKind, ProfilerConfig};
use galen::model::ir::test_fixtures::tiny_meta;
use galen::model::ModelIr;
use galen::search::{LatencyFactory, SearchConfig, SearchDriver};
use galen::testing::FaultPlan;
use galen::util::json::Json;

/// The same config override block `submit_line` sends, reused to hand-build
/// the identical `SearchConfig` when crafting journals directly.
const OVERRIDES: &str = r#"{"episodes": 8, "warmup_episodes": 3, "opt_steps_per_episode": 4, "log_every": 0, "ddpg": {"hidden": [24, 16], "batch": 16, "replay_capacity": 200}}"#;

fn fixture() -> (ModelIr, SensitivityTable) {
    let ir = ModelIr::from_meta(&tiny_meta()).unwrap();
    let sens = SensitivityTable::disabled(ir.layers.len(), &SensitivityConfig::default(), "tiny");
    (ir, sens)
}

fn factory() -> LatencyFactory {
    LatencyFactory::new(
        LatencyKind::Sim,
        HwTarget::cortex_a72(),
        "tiny",
        ProfilerConfig::fast(),
        None,
    )
}

fn submit_line(id: &str, agent: &str, target: f64) -> String {
    format!(
        r#"{{"op":"submit","id":"{id}","spec":{{"agent":"{agent}","target":{target},"preset":"fast","config":{OVERRIDES}}}}}"#
    )
}

/// What `config_from_spec` builds for `submit_line`'s spec (preset `fast`,
/// `log_every` forced to 0, no base seed, then the overrides).
fn job_cfg(agent: AgentKind, target: f64) -> SearchConfig {
    let mut cfg = SearchConfig::fast(agent, target);
    cfg.log_every = 0;
    cfg.apply_json(&Json::parse(OVERRIDES).unwrap()).unwrap();
    cfg
}

fn run_session(script: &str, opts: &ServeOptions) -> (ServeStats, Vec<Json>) {
    let (ir, sens) = fixture();
    let factory = factory();
    let mut out = Vec::new();
    let stats = serve(
        &ir,
        &sens,
        &factory,
        "tiny",
        opts,
        Cursor::new(script.to_string()),
        &mut out,
    )
    .unwrap();
    let responses = String::from_utf8(out)
        .unwrap()
        .lines()
        .map(|l| Json::parse(l).unwrap_or_else(|e| panic!("bad response line '{l}': {e}")))
        .collect();
    (stats, responses)
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("galen_chaos_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// Craft the on-disk state a crash leaves behind: a journal whose job was
/// submitted and running but never reached a terminal status.
fn crashed_journal(dir: &Path, cfg: &SearchConfig) {
    let mut j = ServeJournal::open_append(dir).unwrap();
    j.record_submitted("job-0", cfg).unwrap();
    j.record_status("job-0", JobStatus::Running, None).unwrap();
}

/// Acceptance criterion: a worker panic marks only its own job `failed`
/// (with the panic message as the error payload) while the service keeps
/// accepting and completing new jobs.
#[test]
fn worker_panic_fails_one_job_and_service_keeps_going() {
    let script = format!(
        "{}\n{}\n{}\n{}\n{}\n{}\n{}\n",
        submit_line("a", "pruning", 0.5),
        submit_line("b", "joint", 0.4),
        r#"{"op":"result","id":"ra","job":"job-0","wait":true}"#,
        r#"{"op":"result","id":"rb","job":"job-1","wait":true}"#,
        // the service must still accept and finish work after the panic
        submit_line("c", "quantization", 0.6),
        r#"{"op":"result","id":"rc","job":"job-2","wait":true}"#,
        r#"{"op":"list","id":"ls"}"#,
    );
    let opts = ServeOptions {
        workers: 1, // deterministic: job-0 hits the armed episode fault
        faults: FaultPlan::parse("episode:1:panic").unwrap(),
        ..Default::default()
    };
    let (stats, responses) = run_session(&script, &opts);

    assert_eq!(responses[2].req_str("state").unwrap(), "failed");
    let err = responses[2].req_str("error").unwrap();
    assert!(err.contains("injected fault: panic"), "{err}");
    assert!(err.contains("panicked"), "{err}");
    assert_eq!(responses[3].req_str("state").unwrap(), "done");
    assert_eq!(responses[5].req_str("state").unwrap(), "done");
    assert_eq!(stats.submitted, 3);
    assert_eq!(stats.failed, 1, "only the panicking job fails");
    assert_eq!(stats.completed, 2);
}

/// Acceptance criterion, in-process: resuming an interrupted session
/// reproduces the uninterrupted session's artifact bit for bit — both when
/// no checkpoint survived (restart from episode 0) and when the surviving
/// checkpoint is garbage (discarded, then restart from episode 0).
#[test]
fn resumed_interrupted_job_is_bit_identical_to_clean_run() {
    let cfg = job_cfg(AgentKind::Pruning, 0.5);

    // reference: one uninterrupted protocol-submitted session
    let ref_dir = tmp_dir("ref");
    let script = format!(
        "{}\n{}\n",
        submit_line("a", "pruning", 0.5),
        r#"{"op":"result","job":"job-0","wait":true}"#,
    );
    let (stats, _) = run_session(
        &script,
        &ServeOptions {
            workers: 1,
            results_dir: Some(ref_dir.clone()),
            journal_dir: Some(ref_dir.clone()),
            checkpoint_every: 2,
            ..Default::default()
        },
    );
    assert_eq!(stats.completed, 1);
    let reference = std::fs::read(ref_dir.join("serve_tiny_job-0.json")).unwrap();

    for (tag, garbage_checkpoint) in [("plain", false), ("garbage_ckpt", true)] {
        let dir = tmp_dir(tag);
        crashed_journal(&dir, &cfg);
        if garbage_checkpoint {
            let ckpt = dir.join("checkpoints");
            std::fs::create_dir_all(&ckpt).unwrap();
            std::fs::write(ckpt.join("job-0.json"), b"{\"kind\": \"galen_sear").unwrap();
        }
        let (stats, responses) = run_session(
            r#"{"op":"result","job":"job-0","wait":true}"#,
            &ServeOptions {
                workers: 1,
                results_dir: Some(dir.clone()),
                journal_dir: Some(dir.clone()),
                resume_jobs: true,
                checkpoint_every: 2,
                ..Default::default()
            },
        );
        assert_eq!(stats.resumed, 1, "{tag}");
        assert_eq!(stats.completed, 1, "{tag}");
        assert_eq!(responses[0].req_str("state").unwrap(), "done", "{tag}");
        let resumed = std::fs::read(dir.join("serve_tiny_job-0.json")).unwrap();
        assert_eq!(resumed, reference, "{tag}: artifacts must be bit-identical");
        std::fs::remove_dir_all(&dir).ok();
    }
    std::fs::remove_dir_all(&ref_dir).ok();
}

/// A corrupt-on-read checkpoint (injected at the `checkpoint-read` site) is
/// discarded and the job restarts from episode 0 — same bit-identical
/// outcome, never a panic or a stranded job.
#[test]
fn injected_checkpoint_corruption_self_heals() {
    let cfg = job_cfg(AgentKind::Joint, 0.4);

    let ref_dir = tmp_dir("ckptref");
    crashed_journal(&ref_dir, &cfg);
    let resume_opts = |dir: &Path, faults: FaultPlan| ServeOptions {
        workers: 1,
        results_dir: Some(dir.to_path_buf()),
        journal_dir: Some(dir.to_path_buf()),
        resume_jobs: true,
        checkpoint_every: 1,
        faults,
        ..Default::default()
    };
    let (stats, _) = run_session(
        r#"{"op":"result","job":"job-0","wait":true}"#,
        &resume_opts(&ref_dir, FaultPlan::none()),
    );
    assert_eq!(stats.completed, 1);
    let reference = std::fs::read(ref_dir.join("serve_tiny_job-0.json")).unwrap();

    // same crashed state, but this time a checkpoint file exists (copied
    // from the reference run) and the read of it is corrupted in flight
    let dir = tmp_dir("ckptcorrupt");
    crashed_journal(&dir, &cfg);
    std::fs::create_dir_all(dir.join("checkpoints")).unwrap();
    std::fs::copy(
        ref_dir.join("checkpoints/job-0.json"),
        dir.join("checkpoints/job-0.json"),
    )
    .unwrap();
    let (stats, responses) = run_session(
        r#"{"op":"result","job":"job-0","wait":true}"#,
        &resume_opts(&dir, FaultPlan::parse("checkpoint-read:1:corrupt").unwrap()),
    );
    assert_eq!(stats.completed, 1);
    assert_eq!(responses[0].req_str("state").unwrap(), "done");
    let resumed = std::fs::read(dir.join("serve_tiny_job-0.json")).unwrap();
    assert_eq!(resumed, reference, "discard-and-restart must reproduce the result");

    std::fs::remove_dir_all(&ref_dir).ok();
    std::fs::remove_dir_all(&dir).ok();
}

/// Injected checkpoint-write IO errors are absorbed by the retry/backoff
/// (transient) or logged and skipped (persistent) — either way the job
/// finishes with the same artifact.
#[test]
fn checkpoint_write_failures_never_fail_the_job() {
    let cfg = job_cfg(AgentKind::Quantization, 0.5);
    let run = |tag: &str, faults: FaultPlan| -> Vec<u8> {
        let dir = tmp_dir(tag);
        crashed_journal(&dir, &cfg);
        let (stats, responses) = run_session(
            r#"{"op":"result","job":"job-0","wait":true}"#,
            &ServeOptions {
                workers: 1,
                results_dir: Some(dir.clone()),
                journal_dir: Some(dir.clone()),
                resume_jobs: true,
                checkpoint_every: 1,
                faults,
                ..Default::default()
            },
        );
        assert_eq!(stats.completed, 1, "{tag}");
        assert_eq!(responses[0].req_str("state").unwrap(), "done", "{tag}");
        let bytes = std::fs::read(dir.join("serve_tiny_job-0.json")).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        bytes
    };
    let clean = run("cw_clean", FaultPlan::none());
    // one transient failure: absorbed by the backoff retries
    let transient = run("cw_transient", FaultPlan::parse("checkpoint-write:1:io-error").unwrap());
    // three consecutive failures exhaust the retries: checkpoint skipped
    let persistent = run(
        "cw_persistent",
        FaultPlan::parse(
            "checkpoint-write:1:io-error,checkpoint-write:2:io-error,checkpoint-write:3:io-error",
        )
        .unwrap(),
    );
    assert_eq!(transient, clean);
    assert_eq!(persistent, clean);
}

/// Corrupt-artifact hardening: truncated or garbage JSON in a checkpoint
/// or sweep artifact surfaces as a clean error, never a panic.
#[test]
fn corrupt_artifacts_error_cleanly() {
    let dir = tmp_dir("corrupt_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    let (ir, sens) = fixture();
    let ev = galen::search::SimEvaluator::new(&ir);
    let mut provider = factory().provider(7, &ir).unwrap();
    let mapper = galen::agent::mapper_for(AgentKind::Pruning);

    for (name, bytes) in [
        ("truncated.json", &br#"{"kind": "galen_search_checkpoint", "schema"#[..]),
        ("garbage.json", &b"\x00\xffnot json at all"[..]),
        ("wrong_kind.json", &br#"{"kind": "something_else", "schema_version": 1}"#[..]),
    ] {
        let path = dir.join(name);
        std::fs::write(&path, bytes).unwrap();
        let err = SearchDriver::resume_from_file(
            &path,
            &ir,
            &sens,
            &ev,
            provider.as_mut(),
            mapper.as_ref(),
        )
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(!msg.is_empty(), "{name}: {msg}");
    }

    // a garbage sweep artifact is a clean load error too
    let sweep = dir.join("front.json");
    std::fs::write(&sweep, b"]]]{{{").unwrap();
    assert!(galen::search::ParetoFront::load(&sweep).is_err());
    std::fs::write(&sweep, r#"{"schema_version": 999, "points": []}"#).unwrap();
    let err = format!("{:#}", galen::search::ParetoFront::load(&sweep).unwrap_err());
    assert!(err.contains("schema"), "{err}");

    std::fs::remove_dir_all(&dir).ok();
}

/// The full acceptance scenario against the real binary: a serve process is
/// hard-killed mid-search (injected abort), a plain restart refuses the
/// interrupted journal, and a `--resume-jobs` restart finishes the job with
/// an artifact bit-identical to an uninterrupted run.
#[test]
fn killed_serve_process_resumes_bit_identically() {
    use std::io::Write as _;
    use std::process::{Command, Stdio};

    let run = |dir: &Path, extra: &[&str], faults: Option<&str>, script: &str| {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_galen"));
        cmd.arg("serve")
            .args(["--fixture", "--jobs", "1", "--seed", "7", "--checkpoint-every", "2"])
            .arg("--results")
            .arg(dir)
            .args(extra)
            .env_remove("GALEN_FAULTS")
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .stderr(Stdio::piped());
        if let Some(f) = faults {
            cmd.env("GALEN_FAULTS", f);
        }
        let mut child = cmd.spawn().unwrap();
        // the crash run dies mid-script: a broken pipe here is expected
        let _ = child.stdin.take().unwrap().write_all(script.as_bytes());
        child.wait_with_output().unwrap()
    };
    let submit_and_wait = format!(
        "{}\n{}\n",
        submit_line("a", "joint", 0.4),
        r#"{"op":"result","job":"job-0","wait":true}"#,
    );

    // reference: an uninterrupted run
    let ref_dir = tmp_dir("bin_ref");
    let out = run(&ref_dir, &[], None, &submit_and_wait);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let reference = std::fs::read(ref_dir.join("serve_tiny_job-0.json")).unwrap();

    // crash: the 4th episode aborts the process before its checkpoint
    // lands, leaving an interrupted journal and the episode-2 checkpoint
    let dir = tmp_dir("bin_crash");
    let out = run(&dir, &[], Some("episode:4:abort"), &submit_and_wait);
    assert!(!out.status.success(), "the abort must kill the process");
    assert!(!dir.join("serve_tiny_job-0.json").exists());
    let replayed = galen::coordinator::replay_journal(&dir).unwrap();
    assert_eq!(replayed.len(), 1);
    assert!(!replayed[0].status.is_terminal(), "journal records the interruption");

    // a plain restart must refuse to silently abandon the interrupted job
    let out = run(&dir, &[], None, "");
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("--resume-jobs"), "stderr: {stderr}");

    // --resume-jobs finishes the job from the surviving checkpoint
    let out = run(
        &dir,
        &["--resume-jobs"],
        None,
        "{\"op\":\"result\",\"job\":\"job-0\",\"wait\":true}\n",
    );
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    let result = Json::parse(stdout.lines().next().unwrap()).unwrap();
    assert_eq!(result.req_str("state").unwrap(), "done");
    let resumed = std::fs::read(dir.join("serve_tiny_job-0.json")).unwrap();
    assert_eq!(resumed, reference, "resumed artifact must be bit-identical");

    std::fs::remove_dir_all(&ref_dir).ok();
    std::fs::remove_dir_all(&dir).ok();
}
