//! Observability must be provably inert.
//!
//! The tentpole invariant of the `obs` subsystem: turning metrics and
//! span tracing on or off never changes what the search computes — no
//! RNG stream is consumed, no float is touched, no branch depends on a
//! recorded value.  This test runs every agent kind with observability
//! fully off and again with the metrics registry *and* trace recording
//! on, and asserts the two outcomes are bit-identical (every f64
//! compared through `to_bits`).
//!
//! It also pins the gate semantics themselves (instruments recorded
//! while disabled stay at zero) and validates the artifacts the "on"
//! runs produce: a well-formed Chrome trace-event JSON and a
//! schema-versioned metrics snapshot that round-trips through text.
//!
//! Everything lives in ONE `#[test]` function on purpose: the metrics
//! gate and the trace sink are process-global, and `#[test]` functions
//! inside one integration binary run on parallel threads — two tests
//! toggling the gate would race.  Unit tests in the library crate
//! therefore never touch the gate either (see `obs::metrics`); this
//! binary is the single owner of that state.

use galen::agent::{mapper_for, AgentKind, DdpgConfig};
use galen::eval::{SensitivityConfig, SensitivityTable};
use galen::hw::{CostModel, HwTarget, LatencySimulator};
use galen::model::ir::test_fixtures::tiny_meta;
use galen::model::ModelIr;
use galen::obs;
use galen::search::{run_search, SearchConfig, SearchOutcome, SimEvaluator};
use galen::util::json::Json;

fn setup() -> (ModelIr, SensitivityTable) {
    let ir = ModelIr::from_meta(&tiny_meta()).unwrap();
    let sens = SensitivityTable::disabled(ir.layers.len(), &SensitivityConfig::default(), "tiny");
    (ir, sens)
}

fn sim(seed: u64) -> LatencySimulator {
    LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), seed)
}

fn cfg(agent: AgentKind, episodes: usize) -> SearchConfig {
    let mut cfg = SearchConfig::fast(agent, 0.5);
    cfg.episodes = episodes;
    cfg.warmup_episodes = 3;
    cfg.opt_steps_per_episode = 4;
    cfg.log_every = 0;
    cfg.ddpg = DdpgConfig {
        hidden: (32, 24),
        batch: 24,
        replay_capacity: 400,
        ..Default::default()
    };
    cfg
}

/// Bitwise equality — `assert_eq!` on floats would accept -0.0 == 0.0;
/// the inertness guarantee is stronger than that.
fn assert_outcomes_bit_identical(a: &SearchOutcome, b: &SearchOutcome, what: &str) {
    assert_eq!(a.best_policy, b.best_policy, "{what}: best policy");
    assert_eq!(a.best.episode, b.best.episode, "{what}: best episode index");
    assert_eq!(a.best.reward.to_bits(), b.best.reward.to_bits(), "{what}: best reward");
    assert_eq!(
        a.base_latency_s.to_bits(),
        b.base_latency_s.to_bits(),
        "{what}: base latency"
    );
    assert_eq!(a.history.len(), b.history.len(), "{what}: history length");
    for (i, (x, y)) in a.history.iter().zip(&b.history).enumerate() {
        assert_eq!(x.episode, y.episode, "{what}: history[{i}].episode");
        assert_eq!(x.reward.to_bits(), y.reward.to_bits(), "{what}: history[{i}].reward");
        assert_eq!(
            x.accuracy.to_bits(),
            y.accuracy.to_bits(),
            "{what}: history[{i}].accuracy"
        );
        assert_eq!(
            x.latency_s.to_bits(),
            y.latency_s.to_bits(),
            "{what}: history[{i}].latency"
        );
        assert_eq!(x.macs, y.macs, "{what}: history[{i}].macs");
        assert_eq!(x.bops, y.bops, "{what}: history[{i}].bops");
    }
}

/// A trace file must be a well-formed Chrome trace-event document whose
/// complete events carry every field the viewer needs, including at
/// least one `episode` span from the search driver.
fn assert_trace_well_formed(path: &std::path::Path, what: &str) {
    let doc = Json::read_file(path).unwrap_or_else(|e| panic!("{what}: unreadable trace ({e:#})"));
    assert_eq!(
        doc.req_str("displayTimeUnit").unwrap(),
        "ms",
        "{what}: displayTimeUnit"
    );
    let events = doc.req_arr("traceEvents").unwrap();
    assert!(!events.is_empty(), "{what}: trace recorded no events");
    let mut episode_spans = 0usize;
    for (i, e) in events.iter().enumerate() {
        assert_eq!(e.req_str("ph").unwrap(), "X", "{what}: event[{i}].ph");
        assert_eq!(e.req_str("cat").unwrap(), "galen", "{what}: event[{i}].cat");
        let name = e.req_str("name").unwrap();
        assert!(!name.is_empty(), "{what}: event[{i}] has an empty name");
        for field in ["ts", "dur"] {
            let v = e.req_f64(field).unwrap();
            assert!(v >= 0.0, "{what}: event[{i}].{field} = {v}");
        }
        e.req_usize("pid").unwrap();
        e.req_usize("tid").unwrap();
        if name == "episode" {
            episode_spans += 1;
            let args = e.req("args").unwrap();
            assert!(
                args.get("agent").and_then(Json::as_str).is_some(),
                "{what}: episode span without an agent arg"
            );
        }
    }
    assert!(episode_spans > 0, "{what}: no `episode` span in the trace");
}

/// One test function — see the module doc for why this cannot be split.
#[test]
fn observability_is_inert_and_gates_record() {
    // -------- gate semantics: a disabled registry records nothing --------
    let probe = obs::Counter::register("test_obs_gate_total", &[]);
    obs::metrics::set_enabled(false);
    assert!(!obs::metrics::enabled());
    probe.inc();
    probe.add(10);
    assert_eq!(probe.value(), 0, "disabled counter must stay at zero");
    let probe_g = obs::Gauge::register("test_obs_gate_gauge", &[]);
    probe_g.set(7.0);
    probe_g.add(1.0);
    assert_eq!(probe_g.value(), 0.0, "disabled gauge must stay at zero");
    let probe_h = obs::Histogram::register("test_obs_gate_seconds", &[], &obs::latency_bounds());
    probe_h.observe(0.5);
    assert_eq!(probe_h.count(), 0, "disabled histogram must stay empty");
    obs::metrics::set_enabled(true);
    probe.inc();
    assert_eq!(probe.value(), 1, "re-enabled counter must record again");

    // -------- per-agent bit-identity: all off vs metrics + trace on --------
    let (ir, sens) = setup();
    for agent in [AgentKind::Pruning, AgentKind::Quantization, AgentKind::Joint] {
        let cfg = cfg(agent, 8);
        let ev = SimEvaluator::new(&ir);
        let mapper = mapper_for(agent);

        // reference run: registry gated off, no trace sink
        obs::metrics::set_enabled(false);
        obs::trace::disable();
        let mut sim_off = sim(11);
        let off = run_search(&ir, &sens, &ev, &mut sim_off, mapper.as_ref(), &cfg, None).unwrap();

        // instrumented run: registry on AND every span recorded to disk
        let trace_path = std::env::temp_dir().join(format!(
            "galen_obs_inert_{}_{agent}.json",
            std::process::id()
        ));
        obs::metrics::set_enabled(true);
        obs::trace::enable_to(&trace_path);
        let mut sim_on = sim(11);
        let on = run_search(&ir, &sens, &ev, &mut sim_on, mapper.as_ref(), &cfg, None).unwrap();
        let flushed = obs::trace::flush().unwrap();
        obs::trace::disable();
        assert_eq!(flushed.as_deref(), Some(trace_path.as_path()));

        assert_outcomes_bit_identical(&on, &off, &format!("{agent} obs-on vs obs-off"));
        assert_trace_well_formed(&trace_path, &format!("{agent} trace"));
        std::fs::remove_file(&trace_path).ok();
    }

    // -------- the instrumented runs actually populated the registry --------
    let snap = obs::MetricsSnapshot::capture();
    for agent in ["pruning", "quantization", "joint"] {
        let key = format!("search_episodes_total{{agent=\"{agent}\"}}");
        assert_eq!(
            snap.counter(&key),
            Some(8),
            "episode counter for {agent}: {snap:?}"
        );
        let steps = snap
            .counter(&format!("search_steps_total{{agent=\"{agent}\"}}"))
            .unwrap_or(0);
        assert!(steps >= 8, "step counter for {agent} ({steps})");
    }

    // -------- snapshot text round-trip --------
    let text = snap.to_json().dump();
    let back = obs::MetricsSnapshot::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back.to_json().dump(), text, "snapshot must round-trip");

    // leave the process-global gate the way production code expects it
    obs::metrics::set_enabled(true);
}
