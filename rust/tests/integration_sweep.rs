//! Parallel sweep orchestrator integration: worker-count invariance
//! (4-worker front bit-identical to the sequential sweep), sweep-artifact
//! round-trips, and measurement sharing under the measured backend.
//! Everything runs on the in-code tiny fixture IR — no artifacts needed.

use galen::agent::{AgentKind, DdpgConfig};
use galen::coordinator::{Backend, Session, SessionOptions};
use galen::hw::{LatencyKind, ProfilerConfig};
use galen::model::ir::test_fixtures::tiny_meta;
use galen::model::ModelIr;
use galen::search::{ParetoFront, SearchConfig, SweepGrid};

fn session() -> Session {
    let ir = ModelIr::from_meta(&tiny_meta()).unwrap();
    let mut opts = SessionOptions::new("tiny");
    opts.backend = Backend::Synthetic;
    opts.sensitivity_cache = None;
    opts.profiles_dir = None; // tests must not write repo-level caches
    opts.profiler = ProfilerConfig::fast();
    Session::synthetic(ir, opts)
}

fn proto() -> SearchConfig {
    let mut cfg = SearchConfig::fast(AgentKind::Joint, 0.5);
    cfg.episodes = 16;
    cfg.warmup_episodes = 4;
    cfg.opt_steps_per_episode = 6;
    cfg.log_every = 0;
    cfg.ddpg = DdpgConfig {
        hidden: (32, 24),
        batch: 24,
        replay_capacity: 400,
        ..Default::default()
    };
    cfg
}

#[test]
fn four_worker_sweep_is_bit_identical_to_sequential() {
    let s = session();
    // >= 6 jobs, as in the acceptance protocol: 3 agents x 2 targets
    let grid = SweepGrid::new(
        vec![AgentKind::Pruning, AgentKind::Quantization, AgentKind::Joint],
        vec![0.4, 0.6],
    );
    let seq = s.sweep_parallel(&grid, &proto(), 1).unwrap();
    let par = s.sweep_parallel(&grid, &proto(), 4).unwrap();

    assert_eq!(seq.outcomes.len(), 6);
    assert_eq!(par.outcomes.len(), 6);
    assert_eq!(par.workers, 4);

    // the front — the artifact-visible result — must be bit-identical
    assert_eq!(seq.front, par.front);
    assert!(!seq.front.points.is_empty());

    // and so must every underlying job outcome, field by field
    for (a, b) in seq.outcomes.iter().zip(&par.outcomes) {
        assert_eq!(a.job, b.job);
        assert_eq!(a.outcome.best_policy, b.outcome.best_policy);
        assert_eq!(a.outcome.best.reward, b.outcome.best.reward);
        assert_eq!(a.outcome.best.accuracy, b.outcome.best.accuracy);
        assert_eq!(a.outcome.best.latency_s, b.outcome.best.latency_s);
        assert_eq!(a.outcome.base_latency_s, b.outcome.base_latency_s);
        assert_eq!(a.outcome.history.len(), b.outcome.history.len());
    }

    // serialized artifacts agree byte for byte
    assert_eq!(
        seq.front.to_json().pretty(0),
        par.front.to_json().pretty(0),
        "artifact bytes must be worker-count invariant"
    );
}

#[test]
fn sweep_artifact_writes_and_roundtrips() {
    let s = session();
    let grid = SweepGrid::new(vec![AgentKind::Quantization], vec![0.4, 0.6]);
    let mut cfg = proto();
    cfg.episodes = 8;
    cfg.warmup_episodes = 3;
    let report = s.sweep_parallel(&grid, &cfg, 2).unwrap();

    let dir = std::env::temp_dir().join(format!("galen_sweep_it_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let path = s.save_sweep(&report, &dir).unwrap();
    assert!(path.exists(), "sweep artifact must be written");
    assert!(
        path.ends_with("raspberry-pi-4b-cortex-a72/tiny.json"),
        "artifact layout is sweeps/<target>/<model>.json, got {}",
        path.display()
    );

    let loaded = ParetoFront::load(&path).unwrap();
    assert_eq!(loaded, report.front, "artifact must round-trip exactly");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn measured_backend_sweep_shares_measurements_across_workers() {
    let mut s = session();
    s.opts.latency = LatencyKind::Measured;
    let grid = SweepGrid::new(vec![AgentKind::Quantization], vec![0.5, 0.7]);
    let mut cfg = proto();
    cfg.episodes = 5;
    cfg.warmup_episodes = 2;
    let report = s.sweep_parallel(&grid, &cfg, 2).unwrap();
    assert_eq!(report.outcomes.len(), 2);
    assert!(!report.front.points.is_empty());
    for o in &report.outcomes {
        assert_eq!(o.outcome.latency_backend, "measured");
        assert!(o.outcome.best.latency_s > 0.0);
    }
}
