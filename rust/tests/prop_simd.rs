//! Property suite for the SIMD dispatch layer: every kernel family is
//! bit-identical to the scalar oracle across odd shapes (vector-width
//! tails, k remainders, empty dims), and the equality survives all the way
//! up the stack — a full search trajectory and a packaged `.galen`
//! artifact are byte-for-byte the same under `GALEN_SIMD=off` and
//! `GALEN_SIMD=auto`.
//!
//! On hosts without a detected SIMD ISA the mode flip is a no-op and the
//! suite degenerates to scalar == scalar, which keeps it green (and
//! meaningful as a regression fence) everywhere.

use std::sync::Mutex;

use galen::agent::{mapper_for, AgentKind, DdpgConfig};
use galen::artifact::{self, LatencyClaim, PackInputs};
use galen::compress::DiscretePolicy;
use galen::coordinator::Session;
use galen::eval::{SensitivityConfig, SensitivityTable};
use galen::hw::{CostModel, HwTarget, LatencyKind, LatencySimulator};
use galen::model::ModelIr;
use galen::search::{run_search, SearchConfig, SearchOutcome, SimEvaluator};
use galen::tensor::depthwise::{conv_dw_f32, conv_dw_i8, QuantizedDwWeights};
use galen::tensor::quant::{gemm_i8_i32, gemm_i8_packed_i32, PackedRhsI8};
use galen::tensor::simd::{self, SimdMode};
use galen::tensor::Mat;
use galen::util::rng::Pcg64;

/// Serializes the tests in this binary that flip the process-wide dispatch
/// mode (the harness runs them on parallel threads).
static MODE_LOCK: Mutex<()> = Mutex::new(());

/// Run `f` once under the scalar oracle and once under auto dispatch,
/// returning both results; restores the entry mode.
fn under_both_modes<T>(mut f: impl FnMut() -> T) -> (T, T) {
    let prev = simd::mode();
    simd::set_mode(SimdMode::Scalar);
    let scalar = f();
    simd::set_mode(SimdMode::Auto);
    let auto = f();
    simd::set_mode(prev);
    (scalar, auto)
}

fn random_f32(rng: &mut Pcg64, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
}

fn random_i8(rng: &mut Pcg64, n: usize) -> Vec<i8> {
    (0..n).map(|_| (rng.next_u64() & 0xFF) as u8 as i8).collect()
}

/// Shapes chosen to cross every tail the kernels have: n not a multiple of
/// the 8/4 vector widths, k % 4 remainders, single elements, and empty
/// dims on each axis.
const SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (3, 5, 7),
    (2, 4, 8),
    (4, 261, 9),
    (5, 16, 17),
    (3, 300, 31),
    (2, 7, 33),
    (6, 2, 64),
    (0, 4, 5),
    (4, 0, 5),
    (4, 5, 0),
];

#[test]
fn f32_gemm_family_is_mode_invariant_across_odd_shapes() {
    let _g = MODE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut rng = Pcg64::new(0xF32);
    for &(m, k, n) in SHAPES {
        let a = Mat::from_vec(m, k, random_f32(&mut rng, m * k));
        let b = Mat::from_vec(k, n, random_f32(&mut rng, k * n));
        let bt = Mat::from_vec(n, k, random_f32(&mut rng, n * k));
        let c = Mat::from_vec(m, n, random_f32(&mut rng, m * n));

        let (s, v) = under_both_modes(|| {
            let mut out = Mat::zeros(m, n);
            a.matmul_into(&b, &mut out);
            out.data
        });
        assert_eq!(s, v, "matmul {m}x{k}x{n}");

        let (s, v) = under_both_modes(|| {
            let mut out = Mat::zeros(k, n);
            a.t_matmul_into(&c, &mut out);
            out.data
        });
        assert_eq!(s, v, "t_matmul {m}x{k}x{n}");

        let (s, v) = under_both_modes(|| {
            let mut out = Mat::zeros(m, n);
            a.matmul_t_into(&bt, &mut out);
            out.data
        });
        assert_eq!(s, v, "matmul_t {m}x{k}x{n}");
    }
}

#[test]
fn i8_gemms_are_mode_invariant_across_odd_shapes() {
    let _g = MODE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut rng = Pcg64::new(0x18);
    for &(m, k, n) in SHAPES {
        let a = random_i8(&mut rng, m * k);
        let b = random_i8(&mut rng, k * n);

        let (s, v) = under_both_modes(|| {
            let mut out = vec![0i32; m * n];
            for r in 0..m {
                gemm_i8_i32(&a[r * k..(r + 1) * k], k, &b, n, &mut out[r * n..(r + 1) * n]);
            }
            out
        });
        assert_eq!(s, v, "gemm_i8 {m}x{k}x{n}");

        let packed = PackedRhsI8::pack(&b, k, n, vec![1.0; n]);
        let (s, v) = under_both_modes(|| {
            let mut out = vec![0i32; m * n];
            for r in 0..m {
                gemm_i8_packed_i32(
                    &a[r * k..(r + 1) * k],
                    k,
                    &packed,
                    &mut out[r * n..(r + 1) * n],
                );
            }
            out
        });
        assert_eq!(s, v, "gemm_i8_packed {m}x{k}x{n}");
    }
}

#[test]
fn depthwise_convs_are_mode_invariant() {
    let _g = MODE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut rng = Pcg64::new(0xD4);
    // odd spatial extents and strides; stride 2 always takes the scalar
    // path, so it doubles as a fence that the dispatch gating is correct
    for &(channels, in_sp, kernel, stride) in &[
        (3usize, 9usize, 3usize, 1usize),
        (2, 17, 3, 1),
        (1, 7, 5, 1),
        (4, 5, 1, 1),
        (2, 16, 3, 2),
        (3, 11, 5, 2),
        (1, 1, 3, 1),
        (5, 8, 3, 1),
    ] {
        let out_sp = (in_sp + stride - 1) / stride;
        let input = random_f32(&mut rng, channels * in_sp * in_sp);
        let weights = random_f32(&mut rng, channels * kernel * kernel);
        let tag = format!("c{channels} sp{in_sp} k{kernel} s{stride}");

        let (s, v) = under_both_modes(|| {
            let mut out = vec![0.0f32; channels * out_sp * out_sp];
            conv_dw_f32(&input, channels, in_sp, out_sp, kernel, stride, &weights, &mut out);
            out
        });
        assert_eq!(s, v, "dw_f32 {tag}");

        let qin = random_i8(&mut rng, channels * in_sp * in_sp);
        let qw = QuantizedDwWeights::quantize(&weights, channels, kernel);
        let (s, v) = under_both_modes(|| {
            let mut out = vec![0.0f32; channels * out_sp * out_sp];
            conv_dw_i8(&qin, 0.031_25, channels, in_sp, out_sp, stride, &qw, &mut out);
            out
        });
        assert_eq!(s, v, "dw_i8 {tag}");
    }
}

fn zoo_search() -> SearchOutcome {
    let ir = ModelIr::from_meta(&galen::model::zoo::meta("mobilenetv2s").unwrap()).unwrap();
    let sens = SensitivityTable::disabled(
        ir.layers.len(),
        &SensitivityConfig::default(),
        "mobilenetv2s",
    );
    let ev = SimEvaluator::new(&ir);
    let mapper = mapper_for(AgentKind::Joint);
    let mut sim = LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), 11);
    let mut cfg = SearchConfig::fast(AgentKind::Joint, 0.5);
    cfg.episodes = 6;
    cfg.warmup_episodes = 2;
    cfg.opt_steps_per_episode = 4;
    cfg.eval_batches = 1;
    cfg.log_every = 0;
    cfg.ddpg = DdpgConfig {
        hidden: (32, 24),
        batch: 24,
        replay_capacity: 400,
        ..Default::default()
    };
    run_search(&ir, &sens, &ev, &mut sim, mapper.as_ref(), &cfg, None).unwrap()
}

/// The whole-stack consequence of kernel bit-exactness: a full search
/// trajectory (every episode's reward/accuracy/latency f64 bits, and the
/// best policy) is identical whichever kernel family runs it.
#[test]
fn full_search_trajectory_is_mode_invariant() {
    let _g = MODE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let (scalar, auto) = under_both_modes(zoo_search);
    assert_eq!(scalar.history.len(), auto.history.len());
    for (s, v) in scalar.history.iter().zip(&auto.history) {
        assert_eq!(s.episode, v.episode);
        assert_eq!(s.reward.to_bits(), v.reward.to_bits(), "ep {} reward", s.episode);
        assert_eq!(s.accuracy.to_bits(), v.accuracy.to_bits(), "ep {} accuracy", s.episode);
        assert_eq!(s.latency_s.to_bits(), v.latency_s.to_bits(), "ep {} latency", s.episode);
        assert_eq!(s.macs, v.macs, "ep {} macs", s.episode);
        assert_eq!(s.bops, v.bops, "ep {} bops", s.episode);
    }
    assert_eq!(scalar.base_latency_s.to_bits(), auto.base_latency_s.to_bits());
    assert_eq!(scalar.best_policy, auto.best_policy);
}

/// Packaged `.galen` artifacts are byte-identical across dispatch modes —
/// the acceptance fence that lets artifacts built on SIMD hosts verify on
/// scalar ones and vice versa.
#[test]
fn packed_artifact_bytes_are_mode_invariant() {
    let _g = MODE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let (scalar, auto) = under_both_modes(|| {
        let session = Session::fixture(LatencyKind::Sim, 7).unwrap();
        let policy = DiscretePolicy::reference(&session.ir);
        let (weights, weights_source) = session.packaging_weights().unwrap();
        let mut provider = session.latency_provider(7).unwrap();
        let claim = LatencyClaim {
            latency_s: provider.latency(&session.ir, &policy),
            base_latency_s: provider.latency(&session.ir, &policy),
            backend: provider.backend().to_string(),
        };
        artifact::pack(&PackInputs {
            ir: &session.ir,
            policy: &policy,
            weights: &weights,
            weights_source,
            target: &session.opts.target_hw,
            claim,
            profile_cache: "none".to_string(),
        })
        .unwrap()
        .encode(None)
    });
    assert_eq!(scalar, auto, "artifact bytes differ between dispatch modes");
}
