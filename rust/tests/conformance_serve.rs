//! Protocol conformance: one table of request/response scenarios — every
//! verb and every documented error — executed against all three transports
//! (stdio pipes, TCP, Unix sockets), asserting the response lines are
//! byte-identical across them.  The protocol loop is shared code, but this
//! suite is what keeps it that way: any transport-specific formatting,
//! ordering or field drift fails here before a client sees it.

mod common;

use std::io::Cursor;

use common::{factory, fixture, hello_line, submit_line, with_server, Client};
use galen::coordinator::{serve, NetOptions, ServeOptions, SERVE_PROTOCOL_VERSION};
use galen::util::json::Json;

/// One protocol exchange: a request line and how to check its response.
struct Scenario {
    /// What the scenario covers (assertion messages).
    name: &'static str,
    /// The request line sent verbatim on every transport.
    line: String,
    /// Byte-compare the response across transports.  Off only for
    /// `metrics`: its counters legitimately differ per transport (each
    /// transport label is its own series), so it gets a structural check.
    byte_identical: bool,
}

impl Scenario {
    fn new(name: &'static str, line: impl Into<String>) -> Self {
        Self { name, line: line.into(), byte_identical: true }
    }

    fn structural(name: &'static str, line: impl Into<String>) -> Self {
        Self { name, line: line.into(), byte_identical: false }
    }
}

/// The conformance table.  Order matters: job scenarios run after the
/// submitted job has been waited to completion, so every response is
/// deterministic — which is exactly what makes byte-comparison possible.
fn scenarios() -> Vec<Scenario> {
    vec![
        // -- handshake -------------------------------------------------
        Scenario::new("hello ok", hello_line("h1")),
        Scenario::new(
            "hello version mismatch",
            r#"{"op":"hello","id":"h2","protocol":99}"#,
        ),
        Scenario::new(
            "hello bad require capability",
            r#"{"op":"hello","id":"h3","protocol":2,"require":["submit","teleport"]}"#,
        ),
        Scenario::new(
            "hello unknown key",
            r#"{"op":"hello","id":"h4","protocol":2,"auth":"hunter2"}"#,
        ),
        Scenario::new(
            "hello retry after mismatch succeeds",
            format!(
                r#"{{"op":"hello","id":"h5","protocol":{SERVE_PROTOCOL_VERSION},"require":["submit","result"]}}"#
            ),
        ),
        // -- malformed requests ----------------------------------------
        Scenario::new("bad json", r#"{"op": "status", "#.to_string()),
        Scenario::new("non-object request", "42".to_string()),
        Scenario::new("null request", "null".to_string()),
        Scenario::new("missing op", r#"{"id":"m1"}"#),
        Scenario::new("wrong-typed op", r#"{"op":7,"id":"m2"}"#),
        Scenario::new("unknown op", r#"{"op":"frobnicate","id":"m3"}"#),
        // -- submit error surface --------------------------------------
        Scenario::new(
            "submit without spec",
            r#"{"op":"submit","id":"e1"}"#,
        ),
        Scenario::new(
            "submit bad agent",
            r#"{"op":"submit","id":"e2","spec":{"agent":"nope","target":0.5}}"#,
        ),
        Scenario::new(
            "submit bad preset",
            r#"{"op":"submit","id":"e3","spec":{"agent":"quantization","target":0.5,"preset":"slow"}}"#,
        ),
        Scenario::new(
            "submit unknown spec key",
            r#"{"op":"submit","id":"e4","spec":{"agent":"quantization","target":0.5,"cofig":{}}}"#,
        ),
        Scenario::new(
            "submit unknown config key",
            r#"{"op":"submit","id":"e5","spec":{"agent":"quantization","target":0.5,"config":{"episoddes":5}}}"#,
        ),
        Scenario::new(
            "submit wrong-typed target",
            r#"{"op":"submit","id":"e6","spec":{"agent":"quantization","target":"half"}}"#,
        ),
        Scenario::new(
            "submit variant mismatch",
            r#"{"op":"submit","id":"e7","spec":{"agent":"quantization","target":0.5,"variant":"resnet"}}"#,
        ),
        // -- the happy path --------------------------------------------
        Scenario::new("submit ok", submit_line("s1", "quantization", 0.5)),
        Scenario::new(
            "result wait",
            r#"{"op":"result","id":"r1","job":"job-0","wait":true}"#,
        ),
        Scenario::new("status after done", r#"{"op":"status","id":"st1","job":"job-0"}"#),
        Scenario::new("events full", r#"{"op":"events","id":"ev1","job":"job-0"}"#),
        Scenario::new(
            "events paged",
            r#"{"op":"events","id":"ev2","job":"job-0","since":3}"#,
        ),
        Scenario::new(
            "cancel after done is a no-op",
            r#"{"op":"cancel","id":"c1","job":"job-0"}"#,
        ),
        // -- job error surface -----------------------------------------
        Scenario::new(
            "status unknown job",
            r#"{"op":"status","id":"e8","job":"job-9"}"#,
        ),
        Scenario::new(
            "forget unknown job",
            r#"{"op":"forget","id":"e9","job":"nope"}"#,
        ),
        Scenario::new("forget ok", r#"{"op":"forget","id":"f1","job":"job-0"}"#),
        Scenario::new(
            "events after forget are empty",
            r#"{"op":"events","id":"ev3","job":"job-0"}"#,
        ),
        Scenario::new("list", r#"{"op":"list","id":"ls1"}"#),
        Scenario::structural("metrics", r#"{"op":"metrics","id":"mx1"}"#),
        Scenario::new(
            "metrics unknown key",
            r#"{"op":"metrics","id":"mx2","filter":"serve"}"#,
        ),
        Scenario::new("shutdown", r#"{"op":"shutdown","id":"sd1"}"#),
    ]
}

/// Options shared by every transport run: one worker (deterministic
/// scheduling), in-memory results, no journal, the default seed — so job
/// tokens and search outcomes agree byte-for-byte across transports.
fn conformance_opts() -> ServeOptions {
    ServeOptions { workers: 1, ..Default::default() }
}

/// Run the table over stdio: the whole script goes in as one pipe, the
/// response lines come back in order — `galen serve` without `--listen`.
fn run_stdio(table: &[Scenario]) -> Vec<String> {
    let (ir, sens) = fixture();
    let factory = factory();
    let script: String = table.iter().map(|s| format!("{}\n", s.line)).collect();
    let mut out = Vec::new();
    serve(
        &ir,
        &sens,
        &factory,
        "tiny",
        &conformance_opts(),
        Cursor::new(script),
        &mut out,
    )
    .unwrap();
    String::from_utf8(out).unwrap().lines().map(str::to_string).collect()
}

/// Run the table over one socket client, lock-step (send a line, read its
/// response) so no transport buffering can reorder or coalesce anything.
fn run_client<S: std::io::Read + std::io::Write>(
    client: &mut Client<S>,
    table: &[Scenario],
) -> Vec<String> {
    table
        .iter()
        .map(|s| {
            client.send(&s.line);
            client
                .recv_raw()
                .unwrap_or_else(|| panic!("no response for scenario '{}'", s.name))
        })
        .collect()
}

fn run_tcp(table: &[Scenario]) -> Vec<String> {
    let (_stats, responses) =
        with_server("127.0.0.1:0", &conformance_opts(), &NetOptions::default(), |addr| {
            let mut client = Client::connect_tcp(addr);
            run_client(&mut client, table)
        });
    responses
}

#[cfg(unix)]
fn run_unix(table: &[Scenario]) -> Vec<String> {
    let path = std::env::temp_dir().join(format!("galen_conf_{}.sock", std::process::id()));
    let spec = format!("unix:{}", path.display());
    let (_stats, responses) =
        with_server(&spec, &conformance_opts(), &NetOptions::default(), |addr| {
            let mut client = Client::connect_unix(addr);
            run_client(&mut client, table)
        });
    responses
}

/// Structural checks every transport's responses must satisfy regardless
/// of byte-comparison — the table is self-describing enough to spot-check
/// the interesting rows by name.
fn check_semantics(transport: &str, table: &[Scenario], responses: &[String]) {
    assert_eq!(
        responses.len(),
        table.len(),
        "{transport}: every request line gets exactly one response line"
    );
    for (scenario, raw) in table.iter().zip(responses) {
        let r = Json::parse(raw)
            .unwrap_or_else(|e| panic!("{transport}: '{}' response not json: {e}", scenario.name));
        let ok = r.req_bool("ok").unwrap_or_else(|_| {
            panic!("{transport}: '{}' response missing ok: {raw}", scenario.name)
        });
        match scenario.name {
            "hello ok" | "hello retry after mismatch succeeds" => {
                assert!(ok);
                assert_eq!(
                    r.get("protocol").and_then(Json::as_usize),
                    Some(SERVE_PROTOCOL_VERSION)
                );
                assert!(r.get("capabilities").and_then(Json::as_arr).is_some());
            }
            "hello version mismatch" => {
                assert!(!ok);
                assert_eq!(r.get("client_protocol").and_then(Json::as_usize), Some(99));
                assert_eq!(
                    r.get("server_protocol").and_then(Json::as_usize),
                    Some(SERVE_PROTOCOL_VERSION)
                );
                assert_eq!(r.get("id").and_then(Json::as_str), Some("h2"));
            }
            "hello bad require capability" => {
                assert!(!ok);
                assert!(r.req_str("error").unwrap().contains("teleport"), "{raw}");
            }
            "bad json" | "non-object request" | "null request" => {
                assert!(!ok);
                // unparseable or id-less requests cannot echo an id
                assert!(r.get("id").is_none(), "{raw}");
            }
            "unknown op" => {
                assert!(!ok);
                let err = r.req_str("error").unwrap();
                assert!(err.contains("hello|submit"), "op list missing: {err}");
                assert_eq!(r.get("id").and_then(Json::as_str), Some("m3"));
            }
            "submit ok" => {
                assert!(ok);
                assert_eq!(r.req_str("job").unwrap(), "job-0");
                let token = r.req_str("token").unwrap();
                assert_eq!(token.len(), 16, "token is 16 hex chars: {token}");
                assert!(token.chars().all(|c| c.is_ascii_hexdigit()));
            }
            "result wait" => {
                assert!(ok);
                assert_eq!(r.req_str("state").unwrap(), "done");
                assert!(r.get("outcome").is_some() && r.get("policy").is_some());
            }
            "events full" => {
                assert!(ok);
                assert!(!r.get("events").and_then(Json::as_arr).unwrap().is_empty());
            }
            "events after forget are empty" => {
                assert!(ok);
                assert!(r.get("events").and_then(Json::as_arr).unwrap().is_empty());
            }
            "list" => {
                assert!(ok);
                assert_eq!(r.get("jobs").and_then(Json::as_arr).unwrap().len(), 1);
            }
            "metrics" => {
                assert!(ok);
                assert!(r.get("metrics").is_some());
            }
            "shutdown" => {
                assert!(ok);
                assert_eq!(r.req_str("state").unwrap(), "shutdown");
            }
            name if name.starts_with("submit ") || name.contains("unknown") => {
                assert!(!ok, "{transport}: '{name}' should be refused: {raw}");
            }
            _ => {}
        }
    }
}

/// The acceptance criterion: the same script produces byte-identical
/// response lines on stdio, TCP and (on unix) Unix-socket transports —
/// `metrics` excepted, whose per-transport counters legitimately differ.
#[test]
fn responses_are_byte_identical_across_transports() {
    let table = scenarios();
    let stdio = run_stdio(&table);
    let tcp = run_tcp(&table);
    check_semantics("stdio", &table, &stdio);
    check_semantics("tcp", &table, &tcp);
    for (i, scenario) in table.iter().enumerate() {
        if !scenario.byte_identical {
            continue;
        }
        assert_eq!(
            stdio[i], tcp[i],
            "scenario '{}' differs between stdio and tcp",
            scenario.name
        );
    }
    #[cfg(unix)]
    {
        let unix = run_unix(&table);
        check_semantics("unix", &table, &unix);
        for (i, scenario) in table.iter().enumerate() {
            if !scenario.byte_identical {
                continue;
            }
            assert_eq!(
                stdio[i], unix[i],
                "scenario '{}' differs between stdio and unix",
                scenario.name
            );
        }
    }
}
