#!/usr/bin/env python3
"""Bench-regression gate over BENCH_hot_paths.json.

Compares a freshly produced bench trajectory against the committed
baseline and fails on median-latency regressions beyond a noise
tolerance.  Stdlib-only; CI-runner noise is the enemy, so the gate is
deliberately coarse (default 1.6x) and only watches the curated kernel
and substrate sections — the full file remains available for humans.

Usage:
    bench_gate.py BASELINE.json CURRENT.json [--tolerance 1.6]
                  [--enforce-speedup]

Bootstrap-aware: a missing baseline prints a warning and exits 0 so the
first CI run (which records the baseline) stays green.

With --enforce-speedup, additionally requires the current run's
SIMD-vs-scalar GEMM speedup (meta block) to reach 2x when a SIMD ISA is
active; without the flag the speedups are only reported.
"""

import argparse
import json
import os
import sys

# Sections the gate watches: the kernel substrate the measured-latency
# profiler times, plus the cheap always-present microbenches.  Broad
# search/sweep sections are excluded — their medians move with runner
# core counts, not code quality.
GATED_PREFIXES = (
    "tensor/",
    "replay/",
    "json/",
    "compress/",
)


def load(path):
    with open(path) as f:
        return json.load(f)


def gated(benches):
    return {
        name: entry["p50_ns"]
        for name, entry in benches.items()
        if name.startswith(GATED_PREFIXES) and entry.get("p50_ns")
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument("--tolerance", type=float, default=1.6,
                    help="fail when current p50 exceeds baseline by this factor")
    ap.add_argument("--enforce-speedup", action="store_true",
                    help="require >=2x SIMD GEMM speedup when a SIMD ISA is active")
    args = ap.parse_args()

    if not os.path.exists(args.baseline):
        print(f"bench_gate: no baseline at {args.baseline} — bootstrap run, "
              "nothing to compare (record this run's JSON as the baseline)")
        return 0

    base = load(args.baseline)
    cur = load(args.current)
    base_b = gated(base.get("benches", {}))
    cur_b = gated(cur.get("benches", {}))

    base_isa = base.get("meta", {}).get("simd_isa", "?")
    cur_isa = cur.get("meta", {}).get("simd_isa", "?")
    if base_isa != cur_isa:
        print(f"bench_gate: baseline ISA '{base_isa}' != current ISA '{cur_isa}' — "
              "timings are not comparable across kernel backends; skipping "
              "regression comparison")
        base_b = {}

    failures = []
    compared = 0
    for name, base_ns in sorted(base_b.items()):
        cur_ns = cur_b.get(name)
        if cur_ns is None:
            print(f"bench_gate: '{name}' missing from current run (renamed?)")
            continue
        ratio = cur_ns / base_ns
        compared += 1
        marker = "FAIL" if ratio > args.tolerance else "ok"
        print(f"  {marker:>4}  {ratio:5.2f}x  {name}")
        if ratio > args.tolerance:
            failures.append((name, ratio))

    print(f"bench_gate: compared {compared} entries "
          f"(tolerance {args.tolerance:.2f}x, ISA {cur_isa})")

    meta = cur.get("meta", {})
    speedups = {k: float(v) for k, v in meta.items()
                if k.startswith("simd_") and k.endswith("_speedup")}
    for k, v in sorted(speedups.items()):
        print(f"  {k} = {v:.2f}x")
    if args.enforce_speedup and cur_isa in ("avx2", "neon"):
        gemm_speedups = [v for k, v in speedups.items() if "gemm" in k]
        if gemm_speedups and max(gemm_speedups) < 2.0:
            failures.append(("simd gemm speedup < 2x", max(gemm_speedups)))

    if failures:
        for name, ratio in failures:
            print(f"bench_gate: REGRESSION {name}: {ratio:.2f}x", file=sys.stderr)
        return 1
    print("bench_gate: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
