//! Hardware-substrate explorer, now backed by the measured-latency
//! profiler subsystem: profiles a model variant on the real in-tree kernels
//! (f32 / i8 / packed-i8 GEMM), writes the on-disk profile cache, and
//! prints per-layer measured vs simulated latency side by side — plus the
//! simulator-only exploration the example always had (MIX-vs-INT8
//! crossover, float-only ablation).
//!
//!     cargo run --release --example hw_profiler -- [--variant resnet18s]
//!     cargo run --release --example hw_profiler -- --fixture   # no artifacts
//!
//! `--fixture` uses the in-code tiny test IR, so the example runs (and CI
//! smoke-tests the profiler) without `artifacts/` being built.

use std::path::Path;

use anyhow::Result;
use galen::compress::{DiscretePolicy, QuantMode};
use galen::coordinator::{Backend, Session, SessionOptions};
use galen::hw::{
    mix_supported, CostModel, HwTarget, LatencySimulator, MeasuredProfiler, ProfilerConfig,
};
use galen::model::ir::test_fixtures::tiny_meta;
use galen::model::ModelIr;
use galen::util::cli::Cli;

fn main() -> Result<()> {
    galen::util::logging::init(log::LevelFilter::Info);
    let args = Cli::new("hw_profiler", "measured + simulated latency exploration")
        .opt("variant", "resnet18s", "model variant")
        .opt("profiles", "profiles", "profile-cache root directory")
        .flag("fixture", "use the in-code tiny fixture IR (no artifacts/)")
        .parse()?;

    let (ir, model_tag) = if args.has_flag("fixture") {
        (ModelIr::from_meta(&tiny_meta())?, "tiny".to_string())
    } else {
        let mut opts = SessionOptions::new(args.get("variant"));
        opts.backend = Backend::Synthetic; // structure only; no PJRT needed
        let session = Session::open(opts)?;
        let tag = session.opts.variant.clone();
        (session.ir, tag)
    };
    let target = HwTarget::cortex_a72();
    let sim = LatencySimulator::new(CostModel::new(target.clone()), 1);

    // ---- measured vs simulated per-layer profile ----
    // The fixture's layers are tiny; the fast harness keeps CI smoke cheap.
    let cfg = if args.has_flag("fixture") {
        ProfilerConfig::fast()
    } else {
        ProfilerConfig::default()
    };
    let mut prof = MeasuredProfiler::with_cache(
        target.clone(),
        &model_tag,
        cfg,
        Path::new(args.get("profiles")),
    )?;

    let fp32 = DiscretePolicy::reference(&ir);
    let mut int8 = fp32.clone();
    for l in &mut int8.layers {
        l.quant = QuantMode::Int8;
    }

    println!(
        "{:14} {:>13} {:>13} {:>9} {:>13} {:>8}",
        "layer", "meas fp32", "sim fp32", "sim/meas", "meas int8", "MIX?"
    );
    let meas_fp32 = prof.model_latency_per_layer(&ir, &fp32);
    let sim_fp32 = sim.latency_per_layer(&ir, &fp32);
    let meas_int8 = prof.model_latency_per_layer(&ir, &int8);
    for (((l, mf), sf), mi) in ir.layers.iter().zip(&meas_fp32).zip(&sim_fp32).zip(&meas_int8) {
        println!(
            "{:14} {:>10.3} µs {:>10.3} ms {:>8.0}x {:>10.3} µs {:>8}",
            l.name,
            mf * 1e6,
            sf * 1e3,
            sf / mf,
            mi * 1e6,
            if mix_supported(l, l.cin, l.cout) { "yes" } else { "no" }
        );
    }
    let (meas_total, sim_total): (f64, f64) =
        (meas_fp32.iter().sum(), sim_fp32.iter().sum());
    println!(
        "total fp32: measured {:.3} µs (host kernels) vs simulated {:.3} ms (Cortex-A72 model)",
        meas_total * 1e6,
        sim_total * 1e3
    );
    println!(
        "whole-model INT8 measured speedup: {:.2}x\n",
        meas_total / meas_int8.iter().sum::<f64>()
    );

    // ---- profile cache: write, then show that a re-run re-measures nothing
    let stats = prof.stats();
    if let Some(path) = prof.save()? {
        println!(
            "profile cache: {} entries ({} measured, {} loaded) -> {}",
            stats.entries,
            stats.measured,
            stats.loaded,
            path.display()
        );
    }
    prof.model_latency(&ir, &fp32);
    prof.model_latency(&ir, &int8);
    let again = prof.stats();
    println!(
        "second pass: {} new measurements ({} cache hits)\n",
        again.measured - stats.measured,
        again.hits - stats.hits
    );

    // ---- simulator exploration: whole-model mode comparison ----
    let mode_policy = |q: QuantMode| {
        let mut p = fp32.clone();
        for l in &mut p.layers {
            l.quant = q;
        }
        p
    };
    println!("{:22} {:>12} {:>10}", "whole-model mode (sim)", "latency", "vs fp32");
    let int8_total = sim.latency(&ir, &mode_policy(QuantMode::Int8));
    for (name, q) in [
        ("FP32", QuantMode::Fp32),
        ("INT8", QuantMode::Int8),
        ("MIX 7x7", QuantMode::Mix { w_bits: 7, a_bits: 7 }),
        ("MIX 6x6", QuantMode::Mix { w_bits: 6, a_bits: 6 }),
        ("MIX 4x4", QuantMode::Mix { w_bits: 4, a_bits: 4 }),
        ("MIX 2x2", QuantMode::Mix { w_bits: 2, a_bits: 2 }),
        ("MIX 1x1", QuantMode::Mix { w_bits: 1, a_bits: 1 }),
    ] {
        let t = sim.latency(&ir, &mode_policy(q));
        println!("{:22} {:>9.3} ms {:>9.2}x", name, t * 1e3, sim_total / t);
    }
    println!(
        "\ncrossover check (paper: >6-bit bit-serial is slower than INT8):\n  INT8 {:.3} ms vs MIX6x6 {:.3} ms vs MIX7x7 {:.3} ms",
        int8_total * 1e3,
        sim.latency(&ir, &mode_policy(QuantMode::Mix { w_bits: 6, a_bits: 6 })) * 1e3,
        sim.latency(&ir, &mode_policy(QuantMode::Mix { w_bits: 7, a_bits: 7 })) * 1e3,
    );

    // ---- hardware-specific search motivation: a float-only device ----
    let float_sim = LatencySimulator::new(CostModel::new(target.float_only()), 1);
    println!(
        "\nfloat-only device: INT8 policy gains {:.2}x (vs {:.2}x on the A72)\n => identical policies, different hardware, different optimum — why the\n    search must consume measured target latency.",
        float_sim.latency(&ir, &fp32) / float_sim.latency(&ir, &mode_policy(QuantMode::Int8)),
        sim_total / int8_total,
    );
    Ok(())
}
