//! Hardware-substrate explorer: per-layer latency breakdown of a model
//! variant under FP32 / INT8 / bit-serial modes, the MIX-vs-INT8 crossover
//! (paper §Exploration Range), and the float-only-device ablation that
//! motivates hardware-specific search.
//!
//!     cargo run --release --example hw_profiler -- [--variant resnet18s]

use anyhow::Result;
use galen::compress::{DiscretePolicy, QuantMode};
use galen::coordinator::{Backend, Session, SessionOptions};
use galen::hw::{mix_supported, CostModel, HwTarget, LatencySimulator};
use galen::util::cli::Cli;

fn main() -> Result<()> {
    galen::util::logging::init(log::LevelFilter::Info);
    let args = Cli::new("hw_profiler", "latency-simulator exploration")
        .opt("variant", "resnet18s", "model variant")
        .parse()?;

    let mut opts = SessionOptions::new(args.get("variant"));
    opts.backend = Backend::Synthetic; // structure only; no PJRT needed
    let session = Session::open(opts)?;
    let ir = &session.ir;
    let sim = LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), 1);

    // ---- per-layer fp32 breakdown ----
    let fp32 = DiscretePolicy::reference(ir);
    let per_layer = sim.latency_per_layer(ir, &fp32);
    let total: f64 = per_layer.iter().sum();
    println!("{:14} {:>11} {:>8} {:>12} {:>8}", "layer", "fp32 lat", "share", "MACs", "MIX?");
    for (l, t) in ir.layers.iter().zip(&per_layer) {
        println!(
            "{:14} {:>8.3} ms {:>7.1}% {:>12} {:>8}",
            l.name,
            t * 1e3,
            100.0 * t / total,
            l.macs(),
            if mix_supported(l, l.cin, l.cout) { "yes" } else { "no" }
        );
    }
    println!("total fp32: {:.3} ms\n", total * 1e3);

    // ---- whole-model mode comparison ----
    let mode_policy = |q: QuantMode| {
        let mut p = fp32.clone();
        for l in &mut p.layers {
            l.quant = q;
        }
        p
    };
    println!("{:22} {:>12} {:>10}", "whole-model mode", "latency", "vs fp32");
    let int8_total = sim.latency(ir, &mode_policy(QuantMode::Int8));
    for (name, q) in [
        ("FP32", QuantMode::Fp32),
        ("INT8", QuantMode::Int8),
        ("MIX 7x7", QuantMode::Mix { w_bits: 7, a_bits: 7 }),
        ("MIX 6x6", QuantMode::Mix { w_bits: 6, a_bits: 6 }),
        ("MIX 4x4", QuantMode::Mix { w_bits: 4, a_bits: 4 }),
        ("MIX 2x2", QuantMode::Mix { w_bits: 2, a_bits: 2 }),
        ("MIX 1x1", QuantMode::Mix { w_bits: 1, a_bits: 1 }),
    ] {
        let t = sim.latency(ir, &mode_policy(q));
        println!("{:22} {:>9.3} ms {:>9.2}x", name, t * 1e3, total / t);
    }
    println!(
        "\ncrossover check (paper: >6-bit bit-serial is slower than INT8):\n  INT8 {:.3} ms vs MIX6x6 {:.3} ms vs MIX7x7 {:.3} ms",
        int8_total * 1e3,
        sim.latency(ir, &mode_policy(QuantMode::Mix { w_bits: 6, a_bits: 6 })) * 1e3,
        sim.latency(ir, &mode_policy(QuantMode::Mix { w_bits: 7, a_bits: 7 })) * 1e3,
    );

    // ---- hardware-specific search motivation: a float-only device ----
    let float_sim = LatencySimulator::new(
        CostModel::new(HwTarget::cortex_a72().float_only()),
        1,
    );
    let int8 = mode_policy(QuantMode::Int8);
    println!(
        "\nfloat-only device: INT8 policy gains {:.2}x (vs {:.2}x on the A72)\n => identical policies, different hardware, different optimum — why the\n    search must consume measured target latency.",
        float_sim.latency(ir, &fp32) / float_sim.latency(ir, &int8),
        total / int8_total,
    );

    // ---- pruning sweep on the costliest layer ----
    let (worst, _) = per_layer
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap();
    let l = &ir.layers[worst];
    println!("\npruning sweep on the costliest layer ({}):", l.name);
    for keep_frac in [1.0, 0.75, 0.5, 0.25] {
        let mut p = fp32.clone();
        p.layers[worst].kept_channels = ((l.cout as f64 * keep_frac) as usize).max(1);
        println!(
            "  keep {:>4.0}% -> {:>8.3} ms",
            keep_frac * 100.0,
            sim.latency(ir, &p) * 1e3
        );
    }
    Ok(())
}
