//! Figure 5 driver: sequential prune->quant and quant->prune schemes versus
//! the concurrent joint search, at the same effective target rate.
//!
//!     cargo run --release --example sequential_vs_joint -- \
//!         [--variant micro] [--target 0.2] [--episodes 60]

use anyhow::Result;
use galen::agent::AgentKind;
use galen::coordinator::{policy_report, Session, SessionOptions};
use galen::search::SearchConfig;
use galen::util::cli::Cli;

fn main() -> Result<()> {
    galen::util::logging::init(log::LevelFilter::Info);
    let args = Cli::new("sequential_vs_joint", "Fig 5: sequential vs joint search")
        .opt("variant", "micro", "model variant")
        .opt("target", "0.2", "effective target compression rate")
        .opt("episodes", "60", "episodes per search stage")
        .opt("seed", "7", "seed")
        .parse()?;

    let target = args.get_f64("target")?;
    let mut opts = SessionOptions::new(args.get("variant"));
    opts.seed = args.get_u64("seed")?;
    let session = Session::open(opts)?;

    let mut proto = SearchConfig::new(AgentKind::Joint, target);
    proto.episodes = args.get_usize("episodes")?;
    proto.seed = args.get_u64("seed")?;
    proto.log_every = 25;

    println!("== scheme A: pruning (c1={:.2}) then quantization (c={target:.2}) ==", (1.0 + target) / 2.0);
    let (_pa, a) = session.sequential(AgentKind::Pruning, target, &proto)?;
    println!("{}", policy_report(&session.ir, &a.best_policy));

    println!("== scheme B: quantization first, then pruning ==");
    let (_pb, b) = session.sequential(AgentKind::Quantization, target, &proto)?;
    println!("{}", policy_report(&session.ir, &b.best_policy));

    println!("== scheme C: concurrent joint search ==");
    let mut joint_cfg = proto.clone();
    joint_cfg.agent = AgentKind::Joint;
    let c = session.search(&joint_cfg)?;
    println!("{}", policy_report(&session.ir, &c.best_policy));

    println!(
        "\n{:28} {:>10} {:>10} {:>12} {:>12}",
        "scheme", "rel.lat", "accuracy", "MACs", "BOPs"
    );
    for (name, out) in [
        ("prune -> quant", &a),
        ("quant -> prune", &b),
        ("joint (concurrent)", &c),
    ] {
        println!(
            "{:28} {:>9.1}% {:>9.2}% {:>12.3e} {:>12.3e}",
            name,
            out.relative_latency() * 100.0,
            out.best.accuracy * 100.0,
            out.best.macs as f64,
            out.best.bops as f64
        );
    }
    println!("\npaper appendix: sequential schemes over-use the second method;\njoint balances both (compare the per-layer tables above).");
    Ok(())
}
