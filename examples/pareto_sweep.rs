//! Parallel Pareto-sweep walkthrough: fan a grid of
//! `(agent, latency target)` searches across worker threads, fold the
//! outcomes into a dominance-filtered Pareto front, and write the
//! `sweeps/<target>/<model>.json` artifact.
//!
//!     cargo run --release --example pareto_sweep -- --fixture --jobs 4
//!     cargo run --release --example pareto_sweep -- --variant resnet18s
//!     cargo run --release --example pareto_sweep -- --fixture --jobs 2 --check
//!
//! `--fixture` uses the in-code tiny test IR, so the example runs (and CI
//! smoke-tests the orchestrator) without `artifacts/` being built.
//! `--check` re-runs the sweep on 1 worker and asserts the front is
//! bit-identical — the determinism guarantee of the orchestrator.

use std::path::PathBuf;

use anyhow::Result;
use galen::agent::AgentKind;
use galen::coordinator::{Backend, Session, SessionOptions};
use galen::hw::LatencyKind;
use galen::search::{SearchConfig, SweepGrid};
use galen::util::cli::Cli;

fn main() -> Result<()> {
    galen::util::logging::init(log::LevelFilter::Info);
    let args = Cli::new("pareto_sweep", "parallel Pareto sweep across agents x targets")
        .opt("variant", "resnet18s", "model variant")
        .opt("agents", "pruning,quantization,joint", "agents to sweep")
        .opt("targets", "0.3,0.5", "latency targets c")
        .opt("jobs", "0", "worker threads (0 = all cores)")
        .opt("episodes", "30", "episodes per search job")
        .opt("latency", "sim", "latency backend: sim|measured|hybrid")
        .opt("sweeps", "", "Pareto artifact root (default sweeps/, or GALEN_SWEEPS)")
        .flag("fixture", "use the in-code tiny fixture IR (no artifacts/)")
        .flag("check", "re-run on 1 worker and assert the identical front")
        .parse()?;

    let session = if args.has_flag("fixture") {
        // the one fixture-session recipe (artifact-free tiny IR) lives in
        // Session::fixture, shared with `galen serve --fixture`
        Session::fixture(args.get("latency").parse()?, 7)?
    } else {
        let mut opts = SessionOptions::new(args.get("variant"));
        opts.backend = Backend::Synthetic; // accuracy proxy either way
        opts.latency = args.get("latency").parse()?;
        Session::open(opts)?
    };

    let agents = args
        .get_list("agents")
        .iter()
        .map(|s| s.parse::<AgentKind>())
        .collect::<Result<Vec<_>>>()?;
    let targets = args.get_f64_list("targets")?;
    let grid = SweepGrid::new(agents, targets);

    let mut proto = SearchConfig::fast(AgentKind::Joint, 0.5);
    proto.episodes = args.get_usize("episodes")?;
    proto.log_every = 0;

    let jobs = args.get_usize("jobs")?;
    let report = session.sweep_parallel(&grid, &proto, jobs)?;
    println!(
        "{} jobs on {} workers in {:.1}s ({} latency backend)\n",
        report.outcomes.len(),
        report.workers,
        report.wall_s,
        session.opts.latency
    );
    print!("{}", report.job_table());
    println!(
        "\nPareto front ({} of {} jobs survive dominance + dedup):\n{}",
        report.front.points.len(),
        report.outcomes.len(),
        report.front.table()
    );

    let sweeps_root = if args.get("sweeps").is_empty() {
        galen::sweeps_dir()
    } else {
        PathBuf::from(args.get("sweeps"))
    };
    let path = session.save_sweep(&report, &sweeps_root)?;
    println!("sweep artifact: {}", path.display());

    if args.has_flag("check") {
        if session.opts.latency != LatencyKind::Sim {
            // measured/hybrid runs re-time kernels with fresh wall-clock
            // samples, so cross-run bit-identity only holds for `sim`
            println!(
                "\ndeterminism check skipped: requires --latency sim \
                 (measured/hybrid timings differ run to run)"
            );
            return Ok(());
        }
        println!("\ndeterminism check: re-running on 1 worker ...");
        let seq = session.sweep_parallel(&grid, &proto, 1)?;
        anyhow::ensure!(
            seq.front == report.front,
            "parallel front diverged from the sequential front"
        );
        println!(
            "OK: {}-worker front is bit-identical to the 1-worker front \
             ({:.2}x wall-clock)",
            report.workers,
            seq.wall_s / report.wall_s
        );
    }
    Ok(())
}
