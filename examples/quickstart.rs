//! Quickstart: load the AOT artifacts, evaluate a hand-written compression
//! policy (accuracy via PJRT, latency via the hardware simulator), and
//! compare it against the uncompressed reference.
//!
//!     cargo run --release --example quickstart -- [--variant micro]

use anyhow::Result;
use galen::compress::{DiscretePolicy, QuantMode};
use galen::coordinator::policy_report;
use galen::eval::{Evaluator, Split};
use galen::hw::{CostModel, HwTarget, LatencySimulator};
use galen::runtime::{ArtifactRegistry, PjrtRuntime};
use galen::util::cli::Cli;

fn main() -> Result<()> {
    galen::util::logging::init(log::LevelFilter::Info);
    let args = Cli::new("quickstart", "evaluate a hand-written policy")
        .opt("variant", "micro", "model variant")
        .opt("artifacts", "artifacts", "artifacts directory")
        .parse()?;

    // 1. bring up the PJRT runtime and load everything `make artifacts` built
    let rt = PjrtRuntime::cpu()?;
    let reg = ArtifactRegistry::load(
        &rt,
        std::path::Path::new(args.get("artifacts")),
        args.get("variant"),
    )?;
    let ir = reg.ir.clone();
    let ev = Evaluator::new(rt, reg)?;

    // 2. hardware substrate: the paper's Raspberry Pi 4B target
    let sim = LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), 42);

    // 3. reference policy: no compression
    let reference = DiscretePolicy::reference(&ir);
    let base_acc = ev.accuracy(&reference, Split::Test, 4)?;
    let base_lat = sim.latency(&ir, &reference);
    println!(
        "uncompressed: accuracy {:.2}%  simulated latency {:.2} ms",
        base_acc * 100.0,
        base_lat * 1e3
    );

    // 4. a hand-written mixed policy: INT8 everywhere, plus 4-bit MIX and
    //    50% pruning on the deepest prunable layer
    let mut policy = reference.clone();
    for l in &mut policy.layers {
        l.quant = QuantMode::Int8;
    }
    if let Some(&deep) = ir.prunable_layers().last() {
        policy.layers[deep].kept_channels = (ir.layers[deep].cout / 2).max(1);
        if galen::hw::mix_supported(
            &ir.layers[deep],
            policy.effective_cin(&ir, deep),
            policy.layers[deep].kept_channels,
        ) {
            policy.layers[deep].quant = QuantMode::Mix {
                w_bits: 4,
                a_bits: 4,
            };
        }
    }

    let acc = ev.accuracy(&policy, Split::Test, 4)?;
    let lat = sim.latency(&ir, &policy);
    println!(
        "compressed:   accuracy {:.2}%  simulated latency {:.2} ms ({:.1}% of reference)",
        acc * 100.0,
        lat * 1e3,
        100.0 * lat / base_lat
    );
    println!(
        "MACs {:.3e} -> {:.3e}   BOPs {:.3e} -> {:.3e}",
        reference.macs(&ir) as f64,
        policy.macs(&ir) as f64,
        reference.bops(&ir) as f64,
        policy.bops(&ir) as f64
    );
    println!("\n{}", policy_report(&ir, &policy));
    println!("next: run a real search with `galen search --agent joint --target 0.3`");
    Ok(())
}
