//! End-to-end driver (DESIGN.md "End-to-end validation"): run the full
//! Galen system — PJRT-compiled compressed-model accuracy, hardware-
//! simulator latency, KL sensitivity analysis, DDPG joint search, and
//! post-search fine-tuning through the AOT train-step graph — on a real
//! trained model, logging the reward curve and the paper's headline
//! metrics.  Results land in results/e2e_joint_search.json and are quoted
//! in EXPERIMENTS.md.
//!
//!     cargo run --release --example joint_search_e2e -- \
//!         [--variant resnet18s] [--target 0.3] [--episodes 120]

use anyhow::Result;
use galen::agent::AgentKind;
use galen::coordinator::{policy_report, table1_header, ExperimentRecord, Session, SessionOptions};
use galen::eval::{retrain, RetrainCfg, Split};
use galen::search::SearchConfig;
use galen::util::cli::Cli;

fn main() -> Result<()> {
    galen::util::logging::init(log::LevelFilter::Info);
    let args = Cli::new("joint_search_e2e", "full-system joint compression search")
        .opt("variant", "resnet18s", "model variant")
        .opt("target", "0.3", "target compression rate c")
        .opt("episodes", "120", "search episodes")
        .opt("eval-batches", "2", "validation batches per episode")
        .opt("retrain-steps", "60", "fine-tune steps for the final policy")
        .opt("seed", "7", "seed")
        .parse()?;

    let target = args.get_f64("target")?;
    let mut opts = SessionOptions::new(args.get("variant"));
    opts.seed = args.get_u64("seed")?;
    let t0 = std::time::Instant::now();
    let mut session = Session::open(opts)?;
    log::info!(
        "session up in {:.1}s (artifacts compiled, sensitivity ready)",
        t0.elapsed().as_secs_f64()
    );

    let mut cfg = SearchConfig::new(AgentKind::Joint, target);
    cfg.episodes = args.get_usize("episodes")?;
    cfg.eval_batches = args.get_usize("eval-batches")?;
    cfg.seed = args.get_u64("seed")?;
    cfg.log_every = 10;

    let t1 = std::time::Instant::now();
    let outcome = session.search(&cfg)?;
    let search_secs = t1.elapsed().as_secs_f64();

    // ---- reward curve (compact console plot) ----
    println!("\nreward curve (episode -> reward, new best marked *):");
    let mut best = f64::NEG_INFINITY;
    for h in outcome.history.iter().step_by((cfg.episodes / 30).max(1)) {
        let mark = if h.reward > best { "*" } else { " " };
        best = best.max(h.reward);
        let bar_len = ((h.reward + 3.0).max(0.0) * 12.0) as usize;
        println!(
            "  ep {:4} {mark} {:+.4}  acc {:.3}  rel.lat {:5.1}%  {}",
            h.episode,
            h.reward,
            h.accuracy,
            100.0 * h.latency_s / outcome.base_latency_s,
            "#".repeat(bar_len.min(60))
        );
    }

    // ---- headline row ----
    println!("\n{}", table1_header());
    let rec = ExperimentRecord {
        name: format!("e2e_joint_search_c{:03}", (target * 100.0) as u32),
        config: cfg,
        outcome,
    };
    println!("{}", rec.table1_row());
    println!(
        "\nBest policy:\n{}",
        policy_report(&session.ir, &rec.outcome.best_policy)
    );

    // ---- fine-tune + test accuracy (the paper's reported numbers) ----
    let steps = args.get_usize("retrain-steps")?;
    let test_before;
    let mut test_after;
    {
        let ev = session.evaluator.as_ref().expect("pjrt session");
        test_before = ev.accuracy(&rec.outcome.best_policy, Split::Test, usize::MAX)?;
        test_after = test_before;
    }
    if steps > 0 {
        let t2 = std::time::Instant::now();
        let report = {
            let ev = session.evaluator.as_ref().unwrap();
            retrain(
                ev,
                &rec.outcome.best_policy,
                &RetrainCfg {
                    steps,
                    lr: 3e-3,
                    seed: args.get_u64("seed")?,
                },
            )?
        };
        log::info!(
            "retrained {steps} steps in {:.1}s (loss {:.4} -> {:.4})",
            t2.elapsed().as_secs_f64(),
            report.losses.first().unwrap_or(&0.0),
            report.losses.last().unwrap_or(&0.0)
        );
        let ev = session.evaluator.as_mut().unwrap();
        ev.set_params(&report.params)?;
        test_after = ev.accuracy(&rec.outcome.best_policy, Split::Test, usize::MAX)?;
        ev.reset_params()?;
    }

    let path = rec.save(&session.ir, &galen::results_dir())?;
    log::info!("record saved to {}", path.display());
    println!(
        "\nE2E summary: search {search_secs:.0}s / {} episodes, base acc {:.2}%\n  compressed test acc (raw)       {:.2}%\n  compressed test acc (retrained) {:.2}%\n  relative latency                {:.1}% (target {:.0}%)",
        rec.outcome.history.len(),
        rec.outcome.base_accuracy * 100.0,
        test_before * 100.0,
        test_after * 100.0,
        rec.outcome.relative_latency() * 100.0,
        target * 100.0,
    );
    Ok(())
}
