//! Figure 6 driver: the layer-wise KL sensitivity analysis — activation
//! quantization, weight quantization, and channel pruning probes — printed
//! as console heat-bars and saved to results/.
//!
//!     cargo run --release --example sensitivity_analysis -- [--variant micro]

use anyhow::Result;
use galen::coordinator::{Session, SessionOptions};
use galen::eval::SensitivityConfig;
use galen::util::cli::Cli;

fn bar(omega: f64, max: f64) -> String {
    let frac = if max > 0.0 { (omega / max).clamp(0.0, 1.0) } else { 0.0 };
    "#".repeat((frac * 28.0).round() as usize)
}

fn main() -> Result<()> {
    galen::util::logging::init(log::LevelFilter::Info);
    let args = Cli::new("sensitivity_analysis", "Figure 6: KL sensitivity per layer")
        .opt("variant", "micro", "model variant")
        .flag("paper-grid", "use the paper's 10-point/8-bit probe grid")
        .parse()?;

    let mut opts = SessionOptions::new(args.get("variant"));
    if args.has_flag("paper-grid") {
        opts.sensitivity = SensitivityConfig::paper();
    }
    opts.sensitivity_cache = Some(
        galen::results_dir().join(format!(
            "sensitivity_{}{}.json",
            args.get("variant"),
            if args.has_flag("paper-grid") { "_paper" } else { "" }
        )),
    );
    let session = Session::open(opts)?;
    let sens = &session.sens;

    let all_max = sens
        .prune
        .iter()
        .chain(&sens.quant_w)
        .chain(&sens.quant_a)
        .flatten()
        .map(|p| p.omega)
        .fold(0.0f64, f64::max);

    for (title, series) in [
        ("activation quantization (bits -> Ω)", &sens.quant_a),
        ("weight quantization (bits -> Ω)", &sens.quant_w),
        ("channel pruning (ratio -> Ω)", &sens.prune),
    ] {
        println!("\n=== {title} ===");
        for l in &session.ir.layers {
            println!("{:16}", l.name);
            for p in &series[l.index] {
                println!("   {:>5.2}: {:8.4} {}", p.value, p.omega, bar(p.omega, all_max));
            }
        }
    }

    // trend check the paper reports: later layers more sensitive to quant
    let depth_trend = |series: &Vec<Vec<galen::eval::SensitivityProbe>>| -> f64 {
        let n = series.len();
        let lo: f64 = series[..n / 2]
            .iter()
            .flatten()
            .map(|p| p.omega)
            .sum::<f64>()
            / series[..n / 2].iter().flatten().count().max(1) as f64;
        let hi: f64 = series[n / 2..]
            .iter()
            .flatten()
            .map(|p| p.omega)
            .sum::<f64>()
            / series[n / 2..].iter().flatten().count().max(1) as f64;
        hi / lo.max(1e-12)
    };
    println!(
        "\nlate/early mean-Ω ratio: a-quant {:.2}  w-quant {:.2}  prune {:.2}",
        depth_trend(&sens.quant_a),
        depth_trend(&sens.quant_w),
        depth_trend(&sens.prune)
    );
    println!("(paper Fig 6: ratios > 1 — later layers are more sensitive)");
    Ok(())
}
